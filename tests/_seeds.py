"""One seed knob for the whole suite.

Randomised tests (data generation, PRNG keys, attack probes) derive
their seeds here instead of hard-coding integers, so
``REPRO_TEST_SEED=7 pytest ...`` re-rolls the entire battery — the cheap
way to check an assertion isn't seed-lottery luck — while the default
run stays byte-for-byte reproducible.

Usage:  ``from _seeds import TEST_SEED, derive``
``derive("my-test", 3)`` gives a stable per-call-site seed that still
moves with the knob.
"""
import os

TEST_SEED = int(os.environ.get("REPRO_TEST_SEED", "0"))


def derive(*tags) -> int:
    """Stable seed for a tagged call site, offset by TEST_SEED."""
    h = 0
    for t in tags:
        for ch in str(t):
            h = (h * 1000003 + ord(ch)) % ((1 << 31) - 1)
    return (h + TEST_SEED) % ((1 << 31) - 1)
