"""Property-test import shim: real hypothesis when installed, clean
per-test skips when not (the package is optional — see
requirements-dev.txt), so ``pytest -x -q`` always collects the suite.

Usage in test modules:  ``from _hypothesis_compat import given, settings, st``
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:                                           # pragma: no cover
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(f):
            @pytest.mark.skip(reason="hypothesis not installed")
            def _skipped():
                pass
            _skipped.__name__ = f.__name__
            _skipped.__doc__ = f.__doc__
            return _skipped
        return deco

    def settings(*_args, **_kwargs):
        return lambda f: f

    class _Strategies:
        """Stand-in for ``hypothesis.strategies`` — strategy constructors
        are only evaluated inside ``@given(...)`` calls, whose result is
        discarded by the skip decorator above."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()
