"""Per-architecture smoke tests: reduced variant of each assigned family,
one forward + one train step on CPU; output shapes + no NaNs (deliverable f).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.launch.steps import make_train_step
from repro.models import transformer as tfm
from repro.optim import AdamWConfig, adamw_init

B, S = 2, 64


def _batch(cfg, seed=0):
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (B, S - cfg.prefix_tokens), 0,
                              cfg.vocab_size)
    prefix = None
    if cfg.prefix_tokens:
        prefix = jax.random.normal(key, (B, cfg.prefix_tokens,
                                         cfg.prefix_dim))
    return toks, prefix


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_shapes_and_no_nans(arch):
    cfg = get_reduced(arch)
    assert cfg.n_layers <= 8 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    toks, prefix = _batch(cfg)
    logits, aux = tfm.forward(params, cfg, toks, prefix)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    for v in aux.values():
        assert not bool(jnp.isnan(v))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch):
    cfg = get_reduced(arch)
    params = tfm.init_model(jax.random.PRNGKey(1), cfg)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3, warmup=1,
                                                    total_steps=10)))
    toks, prefix = _batch(cfg, seed=1)
    args = (params, opt, toks) if prefix is None else (params, opt, toks, prefix)
    params2, opt2, metrics = step(*args)
    assert float(metrics["ce"]) > 0 and np.isfinite(float(metrics["ce"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     params, params2)
    assert max(jax.tree.leaves(d)) > 0
    # loss finite on the updated params too (no blow-up)
    loss2, _ = tfm.loss_fn(params2, cfg, toks, prefix)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The full configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "dbrx-132b": (40, 6144, 48, 8, 100352),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 32000),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 65536),
        "qwen3-8b": (36, 4096, 32, 8, 151936),
        "minitron-4b": (32, 3072, 24, 8, 256000),
        "musicgen-medium": (48, 1536, 24, 24, 2048),
        "mamba2-780m": (48, 1536, 0, 0, 50280),
        "qwen3-4b": (36, 2560, 32, 8, 151936),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 151936),
        "qwen1.5-110b": (80, 8192, 64, 8, 152064),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.vocab_size)
    assert got == expected
    # param formula is exact (verified against materialised params in reduced
    # variants; here it guards config edits)
    assert cfg.param_count() > 0


def test_moe_configs():
    dbrx = get_config("dbrx-132b")
    assert dbrx.moe.n_experts == 16 and dbrx.moe.top_k == 4
    qmoe = get_config("qwen2-moe-a2.7b")
    assert (qmoe.moe.n_experts, qmoe.moe.top_k,
            qmoe.moe.n_shared_experts) == (60, 4, 4)
    jamba = get_config("jamba-1.5-large-398b")
    assert jamba.moe.n_experts == 16 and jamba.moe.top_k == 2
    # jamba interleave: 1 attention per 8 layers, MoE every 2nd
    mixers = [s.mixer for s in jamba.period]
    assert mixers.count("attn") == 1 and len(mixers) == 8
    assert [s.ffn for s in jamba.period].count("moe") == 4


def test_param_count_formula_matches_reduced():
    for arch in ARCH_IDS:
        cfg = get_reduced(arch)
        params = tfm.init_model(jax.random.PRNGKey(0), cfg)
        actual = sum(p.size for p in jax.tree.leaves(params))
        assert actual == cfg.param_count(), arch


def test_nominal_param_counts():
    """Full configs land on the published sizes (within 10%)."""
    nominal = {"dbrx-132b": 132e9, "jamba-1.5-large-398b": 398e9,
               "llava-next-mistral-7b": 7.2e9, "qwen3-8b": 8.2e9,
               "mamba2-780m": 0.78e9, "qwen2-moe-a2.7b": 14.3e9,
               "qwen1.5-110b": 111e9}
    for arch, want in nominal.items():
        got = get_config(arch).param_count()
        assert abs(got - want) / want < 0.10, (arch, got, want)
    # active counts for MoE
    assert abs(get_config("dbrx-132b").active_param_count() - 36e9) < 4e9
    assert abs(get_config("qwen2-moe-a2.7b").active_param_count() - 2.7e9) < 0.5e9
