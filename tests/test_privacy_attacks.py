"""Attack probes: unit correctness of the MIA machinery, the closed-form
representation leak of weight uploads, and the leakage-ordering
experiment the ISSUE's acceptance bar names —

    MIA advantage:  DP-DML  <=  DML payloads  <  FedAvg weight uploads

at matched task accuracy.  The e2e config (N=220, K=4, 3 rounds, 20
local epochs, 60%-learnable/40%-random labels, advantage averaged over
all 4 victim clients) was calibrated so the margins hold across seeds
0-2; ``REPRO_TEST_SEED`` re-rolls it.
"""
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parent))
from _seeds import TEST_SEED, derive

from repro.configs.visionnet import reduced
from repro.core import stacking
from repro.core.api import Federation
from repro.core.populations.vision import VisionClients
from repro.core.strategies import get_strategy
from repro.models.visionnet import bce_loss, init_visionnet
from repro.privacy import (cosine_similarity, dense_features,
                           example_gradient, features_from_grad,
                           gradient_inversion, mia_advantage, payload_mia,
                           payload_reconstruction, reconstruction_error,
                           weight_upload_mia)
from repro.privacy.attacks import (collect_client_payloads,
                                   model_example_losses, per_example_bce)

CFG = reduced().replace(image_size=16)


# ---------------------------------------------------------------- scoring
def test_mia_advantage_separated_is_one():
    assert mia_advantage([5.0, 6.0, 7.0], [1.0, 2.0, 3.0]) == 1.0


def test_mia_advantage_identical_is_chance():
    rng = np.random.default_rng(derive("mia-chance"))
    s = rng.normal(size=2000)
    assert mia_advantage(s[:1000], s[1000:]) < 0.1


def test_mia_advantage_orientation():
    # members LOWER than non-members must score ~0, not 1 (the probe
    # negates losses before calling this; getting the sign wrong would
    # silently invert every conclusion)
    assert mia_advantage([1.0, 2.0], [5.0, 6.0]) == 0.0


def test_mia_advantage_empty_raises():
    with pytest.raises(ValueError):
        mia_advantage([], [1.0])
    with pytest.raises(ValueError):
        mia_advantage([1.0], [])


def test_per_example_bce_matches_model_loss_mean():
    rng = np.random.default_rng(derive("bce"))
    p = rng.uniform(0.05, 0.95, size=64).astype(np.float32)
    y = (rng.random(64) > 0.5).astype(np.float32)
    per = per_example_bce(p, y)
    assert per.shape == (64,)
    assert abs(per.mean() - float(bce_loss(p, y))) < 1e-5


def test_model_example_losses_batch_invariant():
    key = jax.random.PRNGKey(derive("mel"))
    params = init_visionnet(key, CFG)
    imgs = np.asarray(jax.random.normal(jax.random.PRNGKey(1),
                                        (37, 16, 16, 3)))
    labs = (np.arange(37) % 2).astype(np.float32)
    a = model_example_losses(params, CFG, imgs, labs, batch=256)
    b = model_example_losses(params, CFG, imgs, labs, batch=8)
    np.testing.assert_allclose(a, b, rtol=1e-5)


# ------------------------------------------------- representation leakage
def test_weight_upload_leaks_features_in_closed_form():
    """The headline gradient-leakage result: one example's gradient hands
    over its penultimate representation exactly (h = gW[:,0]/gb[0]),
    while a payload-distilled surrogate's features stay far off."""
    key = jax.random.PRNGKey(derive("featleak"))
    params = init_visionnet(key, CFG)
    x = jax.random.normal(jax.random.PRNGKey(derive("featleak", "x")),
                          (1, 16, 16, 3))
    g = example_gradient(params, CFG, x, np.array([1.0], np.float32))
    h_true = np.asarray(dense_features(params, CFG, x))[0]
    h_rec = features_from_grad(g)
    assert cosine_similarity(h_true, h_rec) > 0.999
    assert (np.linalg.norm(h_rec - h_true)
            / (np.linalg.norm(h_true) + 1e-12)) < 1e-4

    # matched payload-side baseline: an independently-initialised model
    # (what a payload adversary distills) shares no representation
    other = init_visionnet(jax.random.PRNGKey(derive("featleak", "sur")), CFG)
    h_sur = np.asarray(dense_features(other, CFG, x))[0]
    assert cosine_similarity(h_true, h_sur) < 0.8


def test_features_from_grad_zero_signal_raises():
    fake = {"head": {"w": np.zeros((7, 1)), "b": np.zeros((1,))}}
    with pytest.raises(ValueError):
        features_from_grad(fake)


def test_gradient_inversion_fits_observed_gradient():
    """The optimisation attack converges on the gradient-matching
    objective (the upload tightly constrains the adversary) even though
    VisionNet's pooled convs keep raw pixels non-unique — the assertions
    separate those two facts."""
    key = jax.random.PRNGKey(derive("inv"))
    params = init_visionnet(key, CFG)
    x = jax.random.normal(jax.random.PRNGKey(derive("inv", "x")),
                          (1, 16, 16, 3))
    y = np.array([1.0], np.float32)
    g = example_gradient(params, CFG, x, y)
    x_rec, dist = gradient_inversion(params, CFG, g, (1, 16, 16, 3), y,
                                     jax.random.PRNGKey(derive("inv", "k")),
                                     steps=300)
    assert dist < 0.2                 # objective nearly solved ...
    assert x_rec.shape == (1, 16, 16, 3)
    # ... while the payload-only baseline cannot even fit a meaningful
    # objective: its reconstruction stays at chance (standardised MSE of
    # independent Gaussians ~= 2)
    sur = init_visionnet(jax.random.PRNGKey(derive("inv", "sur")), CFG)
    x_pay = payload_reconstruction(CFG, sur, np.array([0.7], np.float32),
                                   (1, 16, 16, 3),
                                   jax.random.PRNGKey(derive("inv", "p")),
                                   steps=100)
    assert reconstruction_error(x_pay, np.asarray(x)) > 1.0


def test_reconstruction_error_units():
    rng = np.random.default_rng(derive("recerr"))
    x = rng.normal(size=(1, 16, 16, 3))
    assert reconstruction_error(x, x) < 1e-12
    assert reconstruction_error(-3.0 * x + 7.0, x) < 1e-12   # affine+sign ok
    assert reconstruction_error(rng.normal(size=x.shape), x) > 1.0


# ------------------------------------------------------ leakage ordering
def _mia_experiment(seed):
    """The calibrated ordering experiment (see module docstring)."""
    K, R, LE, BS, N = 4, 3, 20, 8, 220
    rng = np.random.default_rng(seed)
    imgs = rng.normal(size=(N, 16, 16, 3)).astype(np.float32)
    labs = (imgs.mean(axis=(1, 2, 3)) > 0).astype(np.float32)
    rand_mask = rng.random(N) < 0.4
    labs[rand_mask] = (rng.random(int(rand_mask.sum())) > 0.5
                       ).astype(np.float32)

    def make_pop(rounds=R):
        return VisionClients(CFG, imgs, labs, n_clients=K, rounds=rounds,
                             local_epochs=LE, batch_size=BS, lr=0.05,
                             seed=seed, record_payloads=True)

    def mem_non(pop, client):
        other = (client + 1) % K
        mem = np.unique(np.concatenate([f[client] for f in pop.fold_log]))
        non = np.setdiff1d(
            np.unique(np.concatenate([f[other] for f in pop.fold_log])), mem)
        return mem, non

    # FedAvg upload tap: run R full rounds, then the (R+1)-th local phase
    # is exactly the upload an eavesdropper/server observes
    pop_fa = make_pop(rounds=R + 1)
    Federation(pop_fa, get_strategy("fedavg")).run(until=R)
    pop_fa.begin_round(R)
    part = list(range(K))
    pop_fa.local_phase(R, part, pop_fa.part_mask(part))
    advs = []
    for c in range(K):
        mem, non = mem_non(pop_fa, c)
        cp = stacking.client_slice(pop_fa.client_params, c)
        advs.append(weight_upload_mia(cp, CFG, imgs, labs, mem, non))
    adv_fa = float(np.mean(advs))

    def payload_probe(pop):
        advs = []
        for c in range(K):
            mem, non = mem_non(pop, c)
            pi, pp = collect_client_payloads(pop.payload_log, imgs, c)
            advs.append(payload_mia(CFG, pi, pp, imgs, labs, mem, non,
                                    jax.random.PRNGKey(1000 + c), steps=300))
        return float(np.mean(advs))

    pop_dml = make_pop()
    Federation(pop_dml, get_strategy("dml")).run()
    pop_dp = make_pop()
    Federation(pop_dp, get_strategy("dp-dml", dp_noise_multiplier=1.0)).run()
    return adv_fa, payload_probe(pop_dml), payload_probe(pop_dp)


def test_leakage_ordering_fedavg_worst_dp_best():
    adv_fa, adv_dml, adv_dp = _mia_experiment(TEST_SEED)
    # weight uploads leak decisively more than prediction payloads
    assert adv_fa > adv_dml + 0.1, (adv_fa, adv_dml)
    # DP noising never increases payload leakage (equality up to probe
    # variance is allowed: payloads already sit near the chance floor)
    assert adv_dp <= adv_dml + 0.08, (adv_dp, adv_dml)
    # and the whole ordering is about leakage, not a broken model: the
    # weight-upload attack actually works
    assert adv_fa > 0.2
