"""Heterogeneous-client DML engine: the per-client model registry, mixed
model-family rounds, partial-participation comm scaling, and bitwise
checkpoint/resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hetero import (HeteroConfig, HeteroTrainer,
                               comm_bytes_per_round, make_lm_pool)
from repro.core.mutual import kl_to_received, mutual_kl_terms
from repro.models import get_client_model

ARCHS3 = ("qwen3-4b", "mamba2-780m", "dbrx-132b")       # dense / ssm / moe


def _tiny_cfg(**kw):
    base = dict(archs=("qwen3-4b", "mamba2-780m"), rounds=2, local_epochs=1,
                batch_size=2, public_batch=2, seed=0)
    base.update(kw)
    return HeteroConfig(**base)


def _pool(n=160, seq=24):
    return make_lm_pool(n, seq, 512, seed=0)


# ---------------------------------------------------------------------------
# registry

def test_registry_resolves_families():
    fams = {a: get_client_model(a).family for a in ARCHS3}
    assert fams == {"qwen3-4b": "dense", "mamba2-780m": "ssm",
                    "dbrx-132b": "moe"}
    assert all(get_client_model(a).kind == "lm" for a in ARCHS3)
    vn = get_client_model("visionnet")
    assert vn.kind == "vision" and vn.n_classes == 2


def test_registry_vision_logits_match_bernoulli():
    """The 2-class lift: softmax(share_logits) must equal [1-p, p]."""
    from repro.configs.visionnet import reduced
    from repro.models.visionnet import visionnet_forward
    cm = get_client_model("visionnet")
    params = cm.init(jax.random.PRNGKey(0))
    imgs = jnp.asarray(np.random.default_rng(0).uniform(
        0, 1, (3, reduced().image_size, reduced().image_size, 3)
    ).astype(np.float32))
    p = np.asarray(visionnet_forward(params, cm.cfg, imgs, train=False))
    soft = np.asarray(jax.nn.softmax(cm.share_logits(params, imgs), axis=-1))
    np.testing.assert_allclose(soft[:, 1], p, atol=1e-5)
    np.testing.assert_allclose(soft[:, 0], 1 - p, atol=1e-5)


def test_registry_rejects_prefix_archs():
    with pytest.raises(ValueError, match="prefix"):
        get_client_model("llava-next-mistral-7b")


def test_mixed_modality_federation_rejected():
    data, labels = _pool(60)
    with pytest.raises(ValueError, match="modalit"):
        HeteroTrainer(_tiny_cfg(archs=("qwen3-4b", "visionnet")), data,
                      labels)


def test_kl_to_received_matches_pairwise_eq2():
    """Per-client Eq. 2 vs received logits == row i of the stacked form."""
    rng = np.random.default_rng(1)
    stack = jnp.asarray(rng.normal(0, 1, (4, 5, 16)).astype(np.float32))
    full = np.asarray(mutual_kl_terms(stack, stack))          # (K, B)
    for i in range(4):
        others = jnp.asarray(np.delete(np.asarray(stack), i, axis=0))
        mine = np.asarray(kl_to_received(stack[i], others))   # (B,)
        np.testing.assert_allclose(mine, full[i], atol=1e-5)


# ---------------------------------------------------------------------------
# engine

def test_engine_round_mixed_families():
    """Transformer + SSM + MoE federate through prediction sharing only."""
    data, labels = _pool()
    cfg = _tiny_cfg(archs=ARCHS3, rounds=1)
    tr = HeteroTrainer(cfg, data, labels)
    # the three client pytrees genuinely differ — averaging is undefined
    structs = {str(jax.tree.structure(p)) for p in tr.client_params}
    assert len(structs) == 3
    h = tr.run()
    tr.evaluate()
    assert len(h.rounds) == 1
    rl = h.rounds[0]
    assert rl.participants == [0, 1, 2]
    assert all(np.isfinite(x) for x in rl.client_loss)
    assert all(np.isfinite(x) for x in rl.kl_loss) and max(rl.kl_loss) > 0
    assert rl.comm_bytes > 0 and h.total_comm_bytes == rl.comm_bytes
    assert len(h.client_eval_loss) == 3
    assert all(np.isfinite(x) for x in h.client_eval_loss)


def test_partial_participation_comm_scales_with_m():
    """Acceptance: an M < K run reports comm_bytes scaling with M, and the
    absent client is bitwise-untouched that round."""
    data, labels = _pool()
    comm = {}
    for m in (0, 2):
        cfg = _tiny_cfg(archs=ARCHS3, rounds=1, participation=m, seed=4)
        tr = HeteroTrainer(cfg, data, labels)
        before = [jax.tree.map(lambda x: np.asarray(x).copy(), p)
                  for p in tr.client_params]
        h = tr.run()
        comm[m] = h.total_comm_bytes
        part = h.rounds[0].participants
        if m == 2:
            assert len(part) == 2
            (absent,) = [c for c in range(3) if c not in part]
            for x, y in zip(jax.tree.leaves(before[absent]),
                            jax.tree.leaves(tr.client_params[absent])):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
            assert h.rounds[0].client_loss[absent] == 0.0
    # comm = E * 2 * M * N_pub * V * 4 -> exactly M/K of the full run
    assert comm[2] * 3 == comm[0] * 2 > 0
    d = comm_bytes_per_round(2, 2 * 24, 512, 1)
    assert comm[2] == d["round"] == d["per_epoch_up"] + d["per_epoch_down"]


def test_checkpoint_resume_bitwise_parity(tmp_path):
    """A save/restore at the round boundary continues bitwise-identically
    to the uninterrupted run (params, opt, comm accounting, fold cursor)."""
    data, labels = _pool()
    cfg = _tiny_cfg(rounds=2, seed=7)
    a = HeteroTrainer(cfg, data, labels)
    a.run()
    b = HeteroTrainer(cfg, data, labels)
    b.run(until=1)
    path = str(tmp_path / "hetero_state")
    b.save_state(path)
    c = HeteroTrainer(cfg, data, labels)
    c.restore_state(path)
    assert c._round == 1 and c.folds.remaining() == b.folds.remaining()
    c.run()
    for pa, pc in zip(jax.tree.leaves(a.client_params),
                      jax.tree.leaves(c.client_params)):
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pc))
    for oa, oc in zip(jax.tree.leaves(a.client_opts),
                      jax.tree.leaves(c.client_opts)):
        np.testing.assert_array_equal(np.asarray(oa), np.asarray(oc))
    assert c.history.total_comm_bytes == a.history.total_comm_bytes
    assert len(c.history.rounds) == len(a.history.rounds) == 2


def test_archs_mismatch_rejected(tmp_path):
    data, labels = _pool(60)
    cfg = _tiny_cfg(rounds=1)
    tr = HeteroTrainer(cfg, data, labels)
    path = str(tmp_path / "st")
    tr.save_state(path)
    other = HeteroTrainer(_tiny_cfg(archs=("qwen3-4b", "qwen3-4b"),
                                    rounds=1), data, labels)
    with pytest.raises(ValueError, match="archs"):
        other.restore_state(path)


def test_trainer_requires_checkpoint_dir_roundtrip(tmp_path):
    """save_state writes through repro.checkpoint: npz + JSON sidecar."""
    data, labels = _pool(60)
    tr = HeteroTrainer(_tiny_cfg(rounds=1), data, labels)
    path = str(tmp_path / "ck")
    tr.save_state(path)
    assert os.path.exists(path + ".npz") and os.path.exists(path + ".json")
