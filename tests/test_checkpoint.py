"""Checkpoint round-trips: nested dicts, lists, mixed dtypes, metadata."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.configs import get_reduced
from repro.models import transformer as tfm
from repro.optim import adamw_init


def test_roundtrip_nested(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.asarray([1, 2], jnp.int32),
                   "c": [jnp.zeros((2,)), jnp.ones((3,), jnp.bfloat16)]},
        "scalar": jnp.float32(3.5),
    }
    path = str(tmp_path / "ckpt")
    checkpoint.save(path, tree, {"step": 7})
    restored, meta = checkpoint.restore(path)
    assert meta["step"] == 7
    flat_a, _ = jax.tree_util.tree_flatten(tree)
    flat_b, _ = jax.tree_util.tree_flatten(restored)
    assert len(flat_a) == len(flat_b)
    for x, y in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))
    assert isinstance(restored["nested"]["c"], list)


def test_roundtrip_client_stacked_federated_state(tmp_path):
    """Client-stacked pytrees (leading K axis on every leaf) + SGD state +
    PRNG key round-trip bitwise — the payload of FederatedTrainer.save_state."""
    from repro.core import stacking
    cfg = get_reduced("qwen3-4b")
    base = tfm.init_model(jax.random.PRNGKey(3), cfg)
    stacked = stacking.broadcast_stack(base, 3)
    opts = stacking.stacked_sgd_init(stacked)
    state = {"client_params": stacked, "client_opts": opts,
             "key": jax.random.PRNGKey(9)}
    path = str(tmp_path / "fed")
    checkpoint.save(path, state, {"round": 2, "scheduler": {"cursor": 5}})
    restored, meta = checkpoint.restore(path)
    assert meta["round"] == 2 and meta["scheduler"]["cursor"] == 5
    want, tw = jax.tree_util.tree_flatten(state)
    got, tg = jax.tree_util.tree_flatten(restored)
    assert tw == tg
    for x, y in zip(want, got):
        assert np.asarray(x).dtype == y.dtype and np.asarray(x).shape == y.shape
        np.testing.assert_array_equal(np.asarray(x), y)
    # every client leaf keeps its leading K axis
    assert all(l.shape[0] == 3 for l in
               jax.tree.leaves(restored["client_params"]))


def test_roundtrip_model_and_opt_state(tmp_path):
    cfg = get_reduced("qwen2-moe-a2.7b")
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    path = str(tmp_path / "model")
    checkpoint.save(path, {"params": params, "opt": opt}, {"arch": cfg.name})
    restored, meta = checkpoint.restore(path)
    assert meta["arch"] == cfg.name
    want = jax.tree_util.tree_flatten(params)[0]
    got = jax.tree_util.tree_flatten(restored["params"])[0]
    assert len(want) == len(got)
    for x, y in zip(want, got):
        assert x.shape == y.shape and x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
