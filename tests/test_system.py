"""End-to-end behaviour: the paper's qualitative claims on a reduced setup.

1. All three frameworks learn (accuracy >> chance on the unseen test set).
2. DML communication is orders of magnitude below weight sharing.
3. Vanilla FL clients end identical (single shared model).
4. The LLM-scale DML path trains and converges clients (kld_avg falls).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.visionnet import reduced as vn_reduced
from repro.core import distributed as D
from repro.core.federated import FederatedConfig, FederatedTrainer
from repro.data.synthetic import make_paper_datasets, make_token_stream
from repro.optim import AdamWConfig


@pytest.fixture(scope="module")
def paper_data():
    vn = vn_reduced()
    return vn, make_paper_datasets(image_size=vn.image_size,
                                   n_train=1200, n_test=400)


@pytest.fixture(scope="module")
def runs(paper_data):
    vn, ((tr_x, tr_y), (te_x, te_y)) = paper_data
    out = {}
    for method in ("dml", "fedavg", "async"):
        fc = FederatedConfig(method=method, n_clients=2, rounds=4,
                             local_epochs=3, batch_size=16, lr=0.05,
                             mutual_epochs=1, delta=2, min_round=0)
        tr = FederatedTrainer(vn, fc, tr_x, tr_y)
        tr.run()
        out[method] = tr.evaluate(te_x, te_y)
    return out


def test_all_frameworks_learn(runs):
    for method, h in runs.items():
        acc = np.mean(h.client_test_acc)
        assert acc > 0.75, (method, h.client_test_acc)


def test_dml_comm_savings(runs):
    assert runs["dml"].total_comm_bytes * 50 < runs["fedavg"].total_comm_bytes
    assert runs["dml"].total_comm_bytes * 10 < runs["async"].total_comm_bytes


def test_fedavg_clients_identical(runs):
    accs = runs["fedavg"].client_test_acc
    assert max(accs) - min(accs) < 1e-9     # single shared model


def test_llm_dml_convergence():
    cfg = get_reduced("qwen3-4b")
    K, B, S = 2, 2, 48
    key = jax.random.PRNGKey(0)
    sp = D.stacked_init(key, cfg, K)
    opt = D.stacked_adamw_init(sp)
    step = jax.jit(D.make_dml_train_step(
        cfg, AdamWConfig(lr=3e-3, warmup=2, total_steps=40), kl_weight=2.0))
    klds, privs = [], []
    for i in range(10):
        toks = jnp.stack([
            jnp.asarray(make_token_stream(B, S, cfg.vocab_size,
                                          seed=100 * i + d, domain=d))
            for d in range(K)])
        pub = jnp.asarray(make_token_stream(B, S, cfg.vocab_size,
                                            seed=9000 + i, domain=K))
        sp, opt, m = step(sp, opt, toks, pub)
        klds.append(float(jnp.mean(m["kld_avg"])))
        privs.append(float(jnp.mean(m["private_loss"])))
    assert privs[-1] < privs[0]             # learning the task
    assert klds[-1] < klds[0]               # clients converging (paper §V)
