"""Fused top-k-gather + sparse-KL kernel vs the XLA oracle.

Covers: forward parity across padded shapes / temperatures / k == V,
custom-VJP gradients vs jax.grad of the ref graph, top-k tie-breaking
determinism, the ops impl switch, and a SparseDML end-to-end Federation
round that is bitwise-identical to the pre-kernel path at impl="ref".
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, st

from repro.core.mutual import (sparse_kl_to_received, sparse_mutual_kl_loss,
                               topk_predictions)
from repro.kernels import ops, ref
from repro.kernels.sparse_kl import sparse_kl_topk


def _logits(K, B, V, seed=0, scale=3.0):
    return jax.random.normal(jax.random.PRNGKey(seed), (K, B, V)) * scale


def _topk(logits, k, temperature=1.0):
    """Received payload: top-k (idx, logp) of each sender's softmax."""
    logp = jax.nn.log_softmax(
        logits.astype(jnp.float32) / temperature, axis=-1)
    vals, idx = jax.lax.top_k(logp, k)
    return idx, vals


def _uniform_w(Kl, J):
    return jnp.full((Kl, J), 1.0 / max(J, 1), jnp.float32)


# ---------------------------------------------------------------------------
# forward parity

@pytest.mark.parametrize("Kl,J,B,V,k,bb,bv", [
    (2, 2, 8, 64, 8, 8, 32),
    (3, 2, 16, 100, 16, 8, 32),    # padded V (100 % 32 != 0)
    (4, 3, 7, 257, 16, 4, 64),     # padded B and V
    (2, 2, 4, 90, 90, 4, 32),      # k == V: no uniform tail
    (1, 3, 6, 128, 8, 4, 128),     # Kl=1 (the hetero per-client form)
])
def test_forward_matches_oracle(Kl, J, B, V, k, bb, bv):
    live = _logits(Kl, B, V, seed=1)
    idx, lp = _topk(_logits(J, B, V, seed=2), k)
    w = _uniform_w(Kl, J)
    want = np.asarray(ref.sparse_kl_pair(live, idx, lp, w))
    got = np.asarray(sparse_kl_topk(live, idx, lp, w, block_b=bb,
                                    block_v=bv, interpret=True))
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("temp", [0.5, 1.0, 2.0, 4.0])
def test_temperature(temp):
    live = _logits(3, 8, 128, seed=3)
    idx, lp = _topk(_logits(2, 8, 128, seed=4), 16, temperature=temp)
    w = _uniform_w(3, 2)
    want = np.asarray(ref.sparse_kl_pair(live, idx, lp, w, temperature=temp))
    got = np.asarray(sparse_kl_topk(live, idx, lp, w, temperature=temp,
                                    block_v=32, interpret=True))
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)


def test_duplicate_indices_multiplicity():
    """Repeated entries in a received index set must be counted once per
    occurrence (gather semantics), exactly like the oracle's gather."""
    Kl, J, B, V, k = 2, 2, 5, 64, 8
    live = _logits(Kl, B, V, seed=5)
    idx, lp = _topk(_logits(J, B, V, seed=6), k)
    idx = idx.at[..., 1].set(idx[..., 0])          # duplicate the argmax
    w = _uniform_w(Kl, J)
    want = np.asarray(ref.sparse_kl_pair(live, idx, lp, w))
    got = np.asarray(sparse_kl_topk(live, idx, lp, w, block_b=4,
                                    block_v=32, interpret=True))
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)


@given(Kl=st.integers(1, 4), J=st.integers(1, 3), B=st.integers(1, 6),
       V=st.integers(4, 90), frac=st.floats(0.1, 1.0),
       seed=st.integers(0, 1000))
def test_property_forward(Kl, J, B, V, frac, seed):
    k = max(1, int(V * frac))
    live = _logits(Kl, B, V, seed=seed, scale=4.0)
    idx, lp = _topk(_logits(J, B, V, seed=seed + 1, scale=4.0), k)
    w = _uniform_w(Kl, J)
    want = np.asarray(ref.sparse_kl_pair(live, idx, lp, w))
    got = np.asarray(sparse_kl_topk(live, idx, lp, w, block_b=4,
                                    block_v=32, interpret=True))
    np.testing.assert_allclose(got, want, atol=5e-5, rtol=5e-5)


# ---------------------------------------------------------------------------
# custom-VJP backward vs AD of the oracle

@pytest.mark.parametrize("Kl,J,B,V,k,bv", [
    (2, 2, 4, 64, 8, 64),
    (3, 2, 6, 100, 16, 32),        # padded V in the streaming backward
    (4, 3, 3, 257, 16, 64),        # padded B and V
    (2, 2, 4, 90, 90, 32),         # k == V
])
def test_vjp_matches_ad_of_oracle(Kl, J, B, V, k, bv):
    live = _logits(Kl, B, V, seed=21)
    idx, lp = _topk(_logits(J, B, V, seed=22), k)
    w = _uniform_w(Kl, J)
    cot = jnp.cos(jnp.arange(Kl * B, dtype=jnp.float32)).reshape(Kl, B)
    g_ref = jax.grad(lambda x: jnp.sum(
        ref.sparse_kl_pair(x, idx, lp, w) * cot))(live)
    g_ker = jax.grad(lambda x: jnp.sum(
        sparse_kl_topk(x, idx, lp, w, block_v=bv,
                       interpret=True) * cot))(live)
    np.testing.assert_allclose(np.asarray(g_ker), np.asarray(g_ref),
                               atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("temp", [0.5, 2.5])
def test_vjp_temperature(temp):
    live = _logits(3, 5, 96, seed=23)
    idx, lp = _topk(_logits(2, 5, 96, seed=24), 12, temperature=temp)
    w = _uniform_w(3, 2)
    g_ref = jax.grad(lambda x: jnp.sum(
        ref.sparse_kl_pair(x, idx, lp, w, temperature=temp)))(live)
    g_ker = jax.grad(lambda x: jnp.sum(sparse_kl_topk(
        x, idx, lp, w, temperature=temp, block_v=32,
        interpret=True)))(live)
    np.testing.assert_allclose(np.asarray(g_ker), np.asarray(g_ref),
                               atol=2e-5, rtol=1e-4)


@given(Kl=st.integers(1, 3), J=st.integers(1, 3), B=st.integers(1, 5),
       V=st.integers(4, 90), seed=st.integers(0, 1000))
def test_property_vjp(Kl, J, B, V, seed):
    k = max(1, V // 3)
    live = _logits(Kl, B, V, seed=seed, scale=4.0)
    idx, lp = _topk(_logits(J, B, V, seed=seed + 7, scale=4.0), k)
    w = _uniform_w(Kl, J)
    g_ref = jax.grad(lambda x: jnp.sum(
        ref.sparse_kl_pair(x, idx, lp, w)))(live)
    g_ker = jax.grad(lambda x: jnp.sum(sparse_kl_topk(
        x, idx, lp, w, block_b=4, block_v=32, interpret=True)))(live)
    np.testing.assert_allclose(np.asarray(g_ker), np.asarray(g_ref),
                               atol=3e-5, rtol=5e-4)


# ---------------------------------------------------------------------------
# top-k tie-breaking determinism (what goes on the wire must not depend on
# who computes it)

def test_topk_tie_breaking_deterministic():
    """Ties break toward the LOWEST vocab index, and two evaluations of
    the share payload are bitwise-identical."""
    B, V, k = 4, 32, 6
    logits = jnp.zeros((2, B, V))                 # all tied
    idx, lp = topk_predictions(logits, k)
    np.testing.assert_array_equal(
        np.asarray(idx), np.broadcast_to(np.arange(k), (2, B, k)))
    idx2, lp2 = topk_predictions(logits, k)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx2))
    np.testing.assert_array_equal(np.asarray(lp), np.asarray(lp2))
    # partially tied: the tied pair keeps index order
    t = jnp.zeros((1, 1, V)).at[0, 0, 10].set(1.0).at[0, 0, 20].set(1.0)
    idx3, _ = topk_predictions(t, 3)
    assert list(np.asarray(idx3[0, 0, :2])) == [10, 20]


# ---------------------------------------------------------------------------
# the ops impl switch + the core.mutual entry points

def test_no_model_grad_impl_downgrade():
    """Every model kernel now carries a custom VJP, so the grad-time
    downgrade hook must be gone: training steps thread the impl they were
    given, unchanged."""
    assert not hasattr(ops, "model_grad_impl")


def test_unknown_impl_raises_at_every_entry_point():
    """ops.* must validate impl against IMPLS and raise — 'xla_flush' must
    never silently run the oracle (regression: ops.ssd treated any unknown
    impl as pallas-eligible / ref)."""
    q = jnp.zeros((1, 4, 2, 8))
    x = jnp.zeros((1, 8, 2, 4))
    dt = jnp.ones((1, 8, 2))
    A = -jnp.ones((2,))
    Bm = jnp.zeros((1, 8, 1, 4))
    logits = jnp.zeros((2, 3, 16))
    w = jnp.ones((2, 2)) / 2
    idx = jnp.zeros((2, 3, 4), jnp.int32)
    lp = jnp.zeros((2, 3, 4))
    calls = [
        lambda: ops.attention(q, q, q, impl="xla_flush"),
        lambda: ops.ssd(x, dt, A, Bm, Bm, impl="xla_flush"),
        lambda: ops.mutual_kl(logits, impl="cuda"),
        lambda: ops.mutual_kl_pair(logits, logits, w, impl="cuda"),
        lambda: ops.sparse_mutual_kl(logits, idx, lp, w, impl="cuda"),
        lambda: ops.set_impl("nope"),
        lambda: ops.resolve_impl("nope"),
    ]
    for call in calls:
        with pytest.raises(ValueError, match="unknown kernel impl"):
            call()


def test_local_train_step_differentiable_under_interpret():
    """make_local_train_step(impl='interpret') differentiates straight
    through the attention/SSD Pallas kernels (their custom VJPs; formerly
    a downgrade to 'ref' — regression for the _pallas_call_jvp_rule
    AssertionError)."""
    from repro.configs import get_reduced
    from repro.core import distributed as D
    from repro.optim import AdamWConfig

    cfg = get_reduced("qwen3-4b")
    opt_cfg = AdamWConfig(lr=1e-3, warmup=2, total_steps=10)
    K, B, S = 2, 2, 16
    key = jax.random.PRNGKey(0)
    sp = D.stacked_init(key, cfg, K)
    opt = D.stacked_adamw_init(sp)
    tokens = jax.random.randint(key, (K, B, S), 0, cfg.vocab_size)
    step = jax.jit(D.make_local_train_step(cfg, opt_cfg, impl="interpret"))
    _, _, metrics = step(sp, opt, tokens)
    assert np.isfinite(np.asarray(metrics["ce"])).all()


def test_ops_impl_switch_routes_to_kernel():
    Kl, J, B, V, k = 2, 2, 6, 80, 8
    live = _logits(Kl, B, V, seed=31)
    idx, lp = _topk(_logits(J, B, V, seed=32), k)
    w = _uniform_w(Kl, J)
    a = ops.sparse_mutual_kl(live, idx, lp, w, impl="ref")
    b = ops.sparse_mutual_kl(live, idx, lp, w, impl="interpret")
    np.testing.assert_allclose(np.asarray(b), np.asarray(a), atol=3e-5,
                               rtol=3e-5)


@pytest.mark.parametrize("entry", ["stacked", "received"])
def test_mutual_entry_points_interpret_vs_ref(entry):
    """core.mutual sparse losses: interpret impl == ref impl, values and
    gradients."""
    K, B, V, k = 3, 5, 96, 12
    stack = _logits(K, B, V, seed=41)
    idx, lp = _topk(stack, k)
    if entry == "stacked":
        f = lambda impl: lambda x: jnp.sum(
            sparse_mutual_kl_loss(x, idx, lp, impl=impl))
        x0 = stack
    else:
        f = lambda impl: lambda x: jnp.sum(
            sparse_kl_to_received(x, idx[1:], lp[1:], impl=impl))
        x0 = stack[0]
    np.testing.assert_allclose(np.asarray(f("interpret")(x0)),
                               np.asarray(f("ref")(x0)),
                               atol=3e-5, rtol=3e-5)
    ga = jax.grad(f("ref"))(x0)
    gb = jax.grad(f("interpret"))(x0)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(ga), atol=2e-5,
                               rtol=1e-4)


def test_explicit_ref_identical_to_default_path():
    """impl='ref' takes the IDENTICAL branch as the pre-kernel default
    (impl=None -> get_impl()): bitwise, not just close.  Pin the ambient
    default to ref so the check holds under REPRO_KERNEL_IMPL overrides."""
    K, B, V, k = 3, 4, 64, 8
    stack = _logits(K, B, V, seed=51)
    idx, lp = _topk(stack, k)
    with ops.use_impl("ref"):
        default = sparse_mutual_kl_loss(stack, idx, lp)      # get_impl()->ref
        d2 = sparse_kl_to_received(stack[0], idx[1:], lp[1:])
    explicit = sparse_mutual_kl_loss(stack, idx, lp, impl="ref")
    np.testing.assert_array_equal(np.asarray(default), np.asarray(explicit))
    e2 = sparse_kl_to_received(stack[0], idx[1:], lp[1:], impl="ref")
    np.testing.assert_array_equal(np.asarray(d2), np.asarray(e2))


# ---------------------------------------------------------------------------
# SparseDML end-to-end through the Federation session layer

def test_sparse_dml_federation_bitwise_at_ref():
    """A SparseDML federation with kernel_impl='ref' is bitwise-identical
    to kernel_impl='auto' on CPU (auto resolves to ref) — i.e. the impl
    plumbing added for the kernel did not perturb the pre-PR hot path."""
    from repro.api import Federation, HeteroClients, SparseDML, make_lm_pool
    if ops.resolve_impl("auto") != "ref":
        pytest.skip("auto does not resolve to ref here (TPU backend or "
                    "REPRO_KERNEL_IMPL override) — bitwise check is "
                    "ref-vs-auto on CPU only")
    data, labels = make_lm_pool(120, 24, 512, seed=0)
    mk = lambda impl: HeteroClients(
        ("qwen3-4b", "mamba2-780m"), data, labels, rounds=2,
        local_epochs=1, batch_size=2, public_batch=2, seed=0,
        kernel_impl=impl)
    pa = Federation(mk("ref"), SparseDML(k=8))
    ha = pa.run()
    pb = Federation(mk("auto"), SparseDML(k=8))
    hb = pb.run()
    assert jax.default_backend() == "cpu"
    la, lb = (jax.tree.leaves(pa.population.client_params),
              jax.tree.leaves(pb.population.client_params))
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert ha.total_comm_bytes == hb.total_comm_bytes
    np.testing.assert_array_equal(ha.rounds[-1].kl_loss, hb.rounds[-1].kl_loss)
