"""Serving-engine correctness: fused multi-step decode, continuous
batching, ensemble modes, sampling, and the checkpoint->serve workflow."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.configs import get_reduced
from repro.launch.serve import greedy_generate
from repro.launch.steps import make_multistep_decode
from repro.models import transformer as tfm
from repro.serve import (ServeEngine, SlotScheduler, combine_logits,
                         load_serving_params, make_router)

ARCHS = ["qwen3-8b", "mamba2-780m", "jamba-1.5-large-398b",
         "llava-next-mistral-7b"]          # dense / SSM / hybrid-MoE / prefix


def _no_drop(cfg):
    if cfg.moe is None:
        return cfg
    m = dataclasses.replace(cfg.moe,
                            capacity_factor=float(cfg.moe.n_experts) /
                            cfg.moe.top_k)
    return cfg.replace(moe=m)


@functools.lru_cache(maxsize=None)
def _setup(arch):
    cfg = _no_drop(get_reduced(arch))
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    B, S0 = 2, 5
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (B, S0), 0, cfg.vocab_size), np.int32)
    prefix = None
    if cfg.prefix_tokens:
        prefix = np.asarray(jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.prefix_tokens, cfg.prefix_dim)),
            np.float32)
    return cfg, params, prompts, prefix


@functools.lru_cache(maxsize=None)
def _stacked(arch="qwen3-4b", K=3):
    cfg = get_reduced(arch)
    params = jax.vmap(lambda k: tfm.init_model(k, cfg))(
        jax.random.split(jax.random.PRNGKey(0), K))
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (2, 5), 0, cfg.vocab_size), np.int32)
    return cfg, params, prompts


# ---------------------------------------------------------------------------
# fused multi-step decode

@pytest.mark.parametrize("arch", ARCHS)
def test_generate_token_identical_to_legacy_loop(arch):
    """The single-scan decode must emit the SAME tokens as the legacy
    per-token Python dispatch loop (greedy), for every cache family."""
    cfg, params, prompts, prefix = _setup(arch)
    G = 7
    legacy = np.asarray(greedy_generate(
        cfg, params, jnp.asarray(prompts), G,
        None if prefix is None else jnp.asarray(prefix)))
    eng = ServeEngine(cfg, params, mode="single", slots=2, max_seq=32)
    assert np.array_equal(eng.generate(prompts, G, prefix=prefix), legacy)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_logits_match_teacher_forced_forward(arch):
    """Prefill+decode cache parity: the logits each emission was sampled
    from must equal the full teacher-forced forward at the same absolute
    positions (same tolerance the per-step serve tests pin)."""
    cfg, params, prompts, prefix = _setup(arch)
    G = 6
    P = cfg.prefix_tokens or 0
    eng = ServeEngine(cfg, params, mode="single", slots=2, max_seq=32)
    toks, lg = eng.generate(prompts, G, prefix=prefix, return_logits=True)
    seq = jnp.concatenate([jnp.asarray(prompts), jnp.asarray(toks)], axis=1)
    full, _ = tfm.forward(params, cfg, seq,
                          None if prefix is None else jnp.asarray(prefix),
                          remat=False)
    S0 = prompts.shape[1]
    # lg[:, t] is the distribution emission t+1 was sampled from == the
    # forward's output at the position of emission t
    np.testing.assert_allclose(
        lg[:, :-1], np.asarray(full[:, P + S0: P + S0 + G - 1]),
        atol=2e-4, rtol=2e-4)


def test_dispatch_count_constant_in_gen_len():
    cfg, params, prompts, _ = _setup("qwen3-8b")
    counts = []
    for G in (3, 11):
        eng = ServeEngine(cfg, params, mode="single", slots=2, max_seq=32)
        eng.generate(prompts, G)
        counts.append(len(eng.dispatch_log))
    assert counts[0] == counts[1] == 3     # prefill + first_token + decode


def test_chunked_decode_chains_bitwise():
    """Two chained chunks == one long scan, tokens AND logits bitwise
    (the property the continuous-batching loop relies on)."""
    cfg, params, prompts, _ = _setup("mamba2-780m")
    S0, G1, G2 = prompts.shape[1], 3, 4
    pre = jax.jit(lambda p, t: tfm.prefill(p, cfg, t, max_seq=32))
    logits, cache = pre(params, jnp.asarray(prompts))
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    key = jax.random.PRNGKey(0)
    long = jax.jit(make_multistep_decode(cfg, G1 + G2))
    t_all, l_all, *_ = long(params, tok, cache, jnp.int32(S0), key)
    short = jax.jit(make_multistep_decode(cfg, G1))
    t1, l1, c, tok2, pos2, key2 = short(params, tok, cache, jnp.int32(S0),
                                        key)
    t2, l2, *_ = jax.jit(make_multistep_decode(cfg, G2))(params, tok2, c,
                                                         pos2, key2)
    assert np.array_equal(np.concatenate([t1, t2], 1), np.asarray(t_all))
    assert np.array_equal(np.concatenate([l1, l2], 1), np.asarray(l_all))


def test_per_slot_vector_pos_matches_scalar():
    """(B,) per-slot positions (the arena path) must be bitwise-equal to
    the scalar-pos path when all slots share a position."""
    cfg, params, prompts, _ = _setup("qwen3-8b")
    S0 = prompts.shape[1]
    _, cache_a = tfm.prefill(params, cfg, jnp.asarray(prompts), max_seq=32)
    _, cache_b = tfm.prefill(params, cfg, jnp.asarray(prompts), max_seq=32)
    tok = jnp.asarray(prompts[:, -1:])
    la, ca = tfm.decode_step(params, cfg, tok, cache_a, jnp.int32(S0))
    lb, cb = tfm.decode_step(params, cfg, tok, cache_b,
                             jnp.full((2,), S0, jnp.int32))
    assert np.array_equal(np.asarray(la), np.asarray(lb))
    for a, b in zip(jax.tree.leaves(ca), jax.tree.leaves(cb)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# sampling

def test_sampling_deterministic_and_top_k_respected():
    cfg, params, prompts, _ = _setup("qwen3-8b")
    kw = dict(mode="single", slots=2, max_seq=32, temperature=0.8, top_k=4)
    a = ServeEngine(cfg, params, seed=7, **kw).generate(prompts, 6)
    b = ServeEngine(cfg, params, seed=7, **kw).generate(prompts, 6)
    c = ServeEngine(cfg, params, seed=8, **kw).generate(prompts, 6)
    assert np.array_equal(a, b) and not np.array_equal(a, c)
    toks, lg = ServeEngine(cfg, params, seed=7, **kw).generate(
        prompts, 6, return_logits=True)
    # every emission after the first must be inside the top-k of the
    # distribution it was sampled from
    order = np.argsort(-lg[:, :-1], axis=-1)[..., :4]
    assert (toks[:, 1:, None] == order).any(-1).all()


def test_greedy_is_temperature_zero():
    cfg, params, prompts, _ = _setup("qwen3-8b")
    g0 = ServeEngine(cfg, params, mode="single", slots=2, max_seq=32,
                     temperature=0.0).generate(prompts, 6)
    legacy = np.asarray(greedy_generate(cfg, params, jnp.asarray(prompts),
                                        6))
    assert np.array_equal(g0, legacy)


# ---------------------------------------------------------------------------
# ensemble modes

def test_ensemble_average_bitwise_matches_vmapped_oracle():
    """The engine's fused scan logits must be BITWISE equal to the
    standalone jitted vmap-decode + mean oracle at every step."""
    cfg, params, prompts = _stacked()
    G, S0 = 5, prompts.shape[1]
    eng = ServeEngine(cfg, params, mode="average", slots=2, max_seq=32)
    toks, lg = eng.generate(prompts, G, return_logits=True)

    pre = jax.jit(lambda ps, t: jax.vmap(
        lambda p: tfm.prefill(p, cfg, t, None, max_seq=32))(ps))
    step = jax.jit(lambda ps, tok, c, pos: (
        lambda lo_c: (jnp.mean(lo_c[0], axis=0), lo_c[1]))(
            jax.vmap(lambda p, cc: tfm.decode_step(p, cfg, tok, cc, pos))(
                ps, c)))
    l0, cache = pre(params, jnp.asarray(prompts))
    tok = jnp.argmax(jnp.mean(l0, 0), -1)[:, None].astype(jnp.int32)
    for t in range(G):
        assert np.array_equal(np.asarray(tok[:, 0]), toks[:, t])
        lo, cache = step(params, tok, cache, jnp.int32(S0 + t))
        assert np.array_equal(np.asarray(lo), lg[:, t])
        tok = jnp.argmax(lo, -1)[:, None].astype(jnp.int32)


def test_ensemble_route_serves_argmin_ce_client():
    cfg, params, prompts = _stacked()
    G = 5
    rtoks = ServeEngine(cfg, params, mode="route", slots=2,
                        max_seq=32).generate(prompts, G)
    cidx, ce = jax.jit(make_router(cfg))(params, jnp.asarray(prompts))
    assert np.array_equal(np.asarray(cidx), np.argmin(np.asarray(ce), 0))
    for b, ci in enumerate(np.asarray(cidx)):
        one = ServeEngine(cfg, jax.tree.map(lambda t: t[ci], params),
                          mode="single", slots=1, max_seq=32)
        assert np.array_equal(rtoks[b], one.generate(prompts[b:b + 1], G)[0])


def test_combine_logits_modes():
    lo = jnp.arange(24, dtype=jnp.float32).reshape(3, 2, 4)
    assert np.array_equal(np.asarray(combine_logits(lo, "average")),
                          np.asarray(lo).mean(0))
    picked = combine_logits(lo, "route", jnp.asarray([2, 0]))
    assert np.array_equal(np.asarray(picked),
                          np.stack([np.asarray(lo)[2, 0],
                                    np.asarray(lo)[0, 1]]))
    with pytest.raises(ValueError):
        combine_logits(lo, "mean")


# ---------------------------------------------------------------------------
# continuous batching

def test_scheduler_budget_and_fifo():
    s = SlotScheduler(2)
    r0 = s.submit([1, 2], 3)
    r1 = s.submit([3], 5)
    r2 = s.submit([4, 5, 6], 2)
    assert s.free_slots() == [0, 1] and s.next_request().rid == r0
    assert s.admit(0).rid == r0 and s.admit(1).rid == r1
    assert not s.record(0, np.asarray([7, 8]))        # 2/3 emitted
    assert s.record(0, np.asarray([9, 10, 11]))       # over-budget dropped
    assert s.done[r0].tolist() == [7, 8, 9]
    assert s.free_slots() == [0] and s.admit(0).rid == r2
    assert s.record(0, np.asarray([1, 2, 3])) and not s.idle
    assert s.record(1, np.asarray([0] * 5)) and s.idle


@pytest.mark.parametrize("arch", ["qwen3-8b", "mamba2-780m"])
def test_continuous_batching_matches_isolated_generate(arch):
    """Mid-flight admission/retirement must not perturb neighbours: every
    request's tokens equal a solo fixed-batch generate of that prompt."""
    cfg, params, _, _ = _setup(arch)
    rng = np.random.default_rng(0)
    eng = ServeEngine(cfg, params, mode="single", slots=2, max_seq=32,
                      chunk=3)
    solo = ServeEngine(cfg, params, mode="single", slots=1, max_seq=32)
    want = {}
    for i in range(5):                     # 5 requests > 2 slots
        p = rng.integers(0, cfg.vocab_size, (3 + i % 3,)).astype(np.int32)
        n = 4 + i % 4
        want[eng.submit(p, n)] = solo.generate(p[None], n)[0]
    got = eng.run()
    assert set(got) == set(want)
    for rid, w in want.items():
        assert np.array_equal(got[rid], w), rid
    assert eng.scheduler.idle


def test_continuous_batching_chunk_size_invariant():
    cfg, params, _ = _stacked()
    rng = np.random.default_rng(1)
    reqs = [(rng.integers(0, cfg.vocab_size, (2 + i,)).astype(np.int32),
             3 + i) for i in range(3)]
    outs = []
    for chunk in (2, 5):
        eng = ServeEngine(cfg, params, mode="average", slots=2, max_seq=32,
                          chunk=chunk)
        rids = [eng.submit(p, n) for p, n in reqs]
        done = eng.run()
        outs.append([done[r] for r in rids])
    for a, b in zip(*outs):
        assert np.array_equal(a, b)


def test_continuous_decode_reuses_one_program():
    cfg, params, _, _ = _setup("qwen3-8b")
    eng = ServeEngine(cfg, params, mode="single", slots=2, max_seq=32,
                      chunk=2)
    rng = np.random.default_rng(2)
    for i in range(4):
        eng.submit(rng.integers(0, cfg.vocab_size, (3,)).astype(np.int32),
                   4)
    eng.run()
    # every decode dispatch hits the SAME jitted chunk program
    assert [k for k in eng._progs if k[0] == "decode"] == [("decode", 2)]
    assert eng.dispatch_counts()["decode"] >= 2


# ---------------------------------------------------------------------------
# checkpoint -> serve

def test_export_for_serving_roundtrip(tmp_path):
    from repro.core.api import Federation
    from repro.core.populations.lm import LMClients
    from repro.core.strategies import DML
    cfg = get_reduced("qwen3-4b")
    fed = Federation(LMClients(cfg, n_clients=2, rounds=1, batch=2, seq=16,
                               seed=0), DML())
    fed.run()
    full, slim = str(tmp_path / "full.npz"), str(tmp_path / "slim.npz")
    fed.save_state(full)
    fed.export_for_serving(slim)
    c1, p1, n1 = load_serving_params(full)
    c2, p2, n2 = load_serving_params(slim)
    assert n1 == n2 == 2 and c1.name == c2.name == cfg.name
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(3), (1, 4),
                                            0, cfg.vocab_size), np.int32)
    for mode in ("average", "route", "single"):
        eng = ServeEngine.from_checkpoint(slim, mode=mode, slots=1,
                                          max_seq=16)
        assert eng.generate(prompts, 3).shape == (1, 3)
        assert eng.n_checkpoint_clients == 2


def test_load_serving_params_rejects_unservable(tmp_path):
    bad = str(tmp_path / "hetero.npz")
    checkpoint.save(bad, {"x": np.zeros(2)},
                    {"engine": "hetero", "arch": "qwen3-4b"})
    with pytest.raises(ValueError, match="not servable"):
        load_serving_params(bad)
    weird = str(tmp_path / "weird.npz")
    checkpoint.save(weird, {"x": np.zeros(2)}, {"arch": "qwen3-4b"})
    with pytest.raises(ValueError, match="unrecognised"):
        load_serving_params(weird)
