"""Algorithm 1 mechanics: FedAvg math, async schedule, fold discipline,
and one short engine round per framework."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.visionnet import reduced
from repro.core import async_fl, fedavg
from repro.core.federated import FederatedConfig, FederatedTrainer
from repro.data.federated import FoldScheduler
from repro.data.synthetic import make_paper_datasets


def test_fedavg_average_exact():
    stacked = {"w": jnp.asarray([[1.0, 2.0], [3.0, 4.0], [5.0, 6.0]])}
    out = fedavg.average_weights(stacked)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               [[3.0, 4.0]] * 3, atol=1e-7)


def test_weighted_average_matches_paper_scoring():
    stacked = {"w": jnp.asarray([[0.0], [10.0]])}
    out = fedavg.weighted_average_weights(stacked, jnp.asarray([1.0, 3.0]))
    np.testing.assert_allclose(np.asarray(out["w"]), [[7.5]] * 2, atol=1e-6)


def test_stack_unstack_roundtrip():
    trees = [{"a": jnp.full((2,), i), "b": [jnp.full((3,), -i)]}
             for i in range(4)]
    stacked = fedavg.stack_params(trees)
    back = fedavg.unstack_params(stacked, 4)
    for orig, got in zip(trees, back):
        assert jax.tree.all(jax.tree.map(
            lambda x, y: bool(jnp.all(x == y)), orig, got))


def test_async_layer_schedule():
    """Algorithm 1 lines 12-14: deep iff (i+1) % delta == 0 and i >= 5."""
    sched = [async_fl.layer_schedule(i, delta=3, min_round=5)
             for i in range(12)]
    deep_rounds = [i for i, s in enumerate(sched) if s == "deep"]
    assert deep_rounds == [5, 8, 11]


def test_async_update_weights_partial():
    stacked = {"shallow": jnp.asarray([[0.0], [2.0]]),
               "deep": jnp.asarray([[0.0], [2.0]])}
    mask = {"shallow": True, "deep": False}
    avg = fedavg.average_weights(stacked)
    out = async_fl.update_weights(stacked, avg, mask, "shallow")
    np.testing.assert_allclose(np.asarray(out["shallow"]), [[1.0], [1.0]])
    np.testing.assert_allclose(np.asarray(out["deep"]), [[0.0], [2.0]])
    out_deep = async_fl.update_weights(stacked, avg, mask, "deep")
    np.testing.assert_allclose(np.asarray(out_deep["deep"]), [[1.0], [1.0]])


def test_fold_scheduler_budget():
    """Fold <- (1+K) x R + 1, disjoint, class-balanced."""
    labels = np.arange(220) % 2
    K, R = 3, 4
    fs = FoldScheduler(labels, K, R, seed=0)
    assert fs.n_folds == (1 + K) * R + 1
    seen = set()
    for _ in range(fs.n_folds):
        f = fs.pop()
        frac = labels[f].mean()
        assert 0.3 < frac < 0.7              # stratification
        assert not (set(f.tolist()) & seen)  # disjoint
        seen.update(f.tolist())
    assert len(seen) == 220
    with pytest.raises(AssertionError):
        fs.pop()


@pytest.mark.parametrize("method", ["dml", "fedavg", "async"])
def test_engine_one_round(method):
    vn = reduced()
    (tr_x, tr_y), (te_x, te_y) = make_paper_datasets(
        image_size=vn.image_size, n_train=240, n_test=80)
    fc = FederatedConfig(method=method, n_clients=2, rounds=1,
                         local_epochs=1, batch_size=16)
    tr = FederatedTrainer(vn, fc, tr_x, tr_y)
    h = tr.run()
    h = tr.evaluate(te_x, te_y)
    assert len(h.rounds) == 1
    assert len(h.client_test_acc) == 2
    assert all(np.isfinite(l) for l in h.rounds[0].client_loss)
    assert h.total_comm_bytes > 0
    if method == "fedavg":
        # vanilla FL: all clients identical after sync (paper Table II row 1)
        l0 = jax.tree.leaves(tr.client_params)[0]
        np.testing.assert_allclose(np.asarray(l0[0]), np.asarray(l0[1]),
                                   atol=1e-7)


def test_non_iid_scheduler_discipline():
    """NonIIDScheduler: same pop order/budget as Algorithm 1, skewed clients,
    balanced shared folds, full partition."""
    from repro.data.federated import NonIIDScheduler
    labels = np.arange(600) % 2
    K, R = 3, 4
    sch = NonIIDScheduler(labels, K, R, alpha=0.2, seed=0)
    assert sch.n_folds == (1 + K) * R + 1
    seen = []
    init = sch.pop()                       # global-init fold (balanced)
    assert 0.3 < labels[init].mean() < 0.7
    seen.extend(init.tolist())
    client_fracs = [[] for _ in range(K)]
    for r in range(R):
        for c in range(K):
            f = sch.pop()
            if len(f) > 5:
                client_fracs[c].append(labels[f].mean())
            seen.extend(f.tolist())
        pub = sch.pop()                    # shared fold (balanced)
        assert 0.3 < labels[pub].mean() < 0.7
        seen.extend(pub.tolist())
    assert sorted(seen) == list(range(600))       # exact partition
    means = [np.mean(fr) for fr in client_fracs if fr]
    assert max(means) - min(means) > 0.15         # visible skew
    with pytest.raises(AssertionError):
        sch.pop()


def test_engine_non_iid_round():
    """The paper's future-work setting runs end-to-end."""
    vn = reduced()
    (tr_x, tr_y), (te_x, te_y) = make_paper_datasets(
        image_size=vn.image_size, n_train=400, n_test=80)
    fc = FederatedConfig(method="dml", n_clients=2, rounds=1,
                         local_epochs=1, batch_size=8, non_iid_alpha=0.3)
    tr = FederatedTrainer(vn, fc, tr_x, tr_y)
    h = tr.run()
    h = tr.evaluate(te_x, te_y)
    assert len(h.rounds) == 1 and all(np.isfinite(h.client_test_acc))


def test_schedulers_pop_order_parity():
    """NonIIDScheduler must follow FoldScheduler's Algorithm-1 pop order:
    one shared init fold, then per round K client folds + one shared fold,
    with identical budgets and identical ``remaining()`` trajectories."""
    from repro.data.federated import NonIIDScheduler
    labels = np.arange(660) % 2
    K, R = 3, 4
    iid = FoldScheduler(labels, K, R, seed=0)
    nid = NonIIDScheduler(labels, K, R, alpha=0.2, seed=0)
    assert iid.n_folds == nid.n_folds == (1 + K) * R + 1
    assert iid.remaining() == nid.remaining() == iid.n_folds
    iid.pop(); nid.pop()                     # shared init fold
    for r in range(R):
        for c in range(K):
            iid.pop(); nid.pop()
            assert iid.remaining() == nid.remaining()
        pub_i, pub_n = iid.pop(), nid.pop()  # shared per-round fold
        # shared folds stay class-balanced under both disciplines
        assert 0.3 < labels[pub_i].mean() < 0.7
        assert 0.3 < labels[pub_n].mean() < 0.7
    assert iid.remaining() == nid.remaining() == 0
    for sch in (iid, nid):
        with pytest.raises(AssertionError):
            sch.pop()


@pytest.mark.parametrize("alpha", [0.0, 0.3])
def test_pop_round_budget_exhaustion(alpha):
    """pop_round consumes exactly K folds/round; the budget runs dry at
    the Algorithm-1 count for both scheduler flavours."""
    from repro.data.federated import NonIIDScheduler
    labels = np.arange(500) % 2
    K, R = 2, 3
    sch = (NonIIDScheduler(labels, K, R, alpha=alpha, seed=1) if alpha
           else FoldScheduler(labels, K, R, seed=1))
    sch.pop()                                       # init fold
    for r in range(R):
        folds, idx, mask = sch.pop_round(K, local_epochs=2, batch_size=8)
        assert len(folds) == K
        assert sch.remaining() == (K + 1) * (R - r) - K
        sch.pop()                                   # shared fold
    assert sch.remaining() == 0
    with pytest.raises(AssertionError):
        sch.pop_round(K, 2, 8)


def test_round_batch_indices_fixed_shape():
    """The (K, T, B) plan: T = epochs * max steps, per-epoch drop-last
    permutations, real steps unmasked, padding masked and cycled."""
    from repro.data.federated import round_batch_indices
    big = np.arange(100, 180)          # 80 -> 5 steps of 16
    small = np.arange(500, 535)        # 35 -> 2 steps of 16
    idx, mask = round_batch_indices([big, small], local_epochs=2,
                                    batch_size=16, seed=3)
    assert idx.shape == (2, 10, 16) and mask.shape == (2, 10)
    # client 0: every step real; client 1: 2 of 5 per epoch
    assert mask[0].tolist() == [1.0] * 10
    assert mask[1].tolist() == [1, 1, 0, 0, 0] * 2
    # indices come only from the right fold
    assert set(idx[0].ravel()) <= set(big.tolist())
    assert set(idx[1].ravel()) <= set(small.tolist())
    # real steps within one epoch never repeat an example (permutation)
    epoch0 = idx[0, :5].ravel()
    assert len(np.unique(epoch0)) == len(epoch0)
    real1 = idx[1, :2].ravel()
    assert len(np.unique(real1)) == len(real1)
    # deterministic in seed
    idx2, _ = round_batch_indices([big, small], 2, 16, seed=3)
    np.testing.assert_array_equal(idx, idx2)
    # empty fold: fully masked, shape preserved
    idx3, mask3 = round_batch_indices([big, np.array([], np.int64)], 1, 16)
    assert idx3.shape == (2, 5, 16) and mask3[1].sum() == 0


def test_dml_round_is_three_dispatches_k5():
    """Acceptance: a full DML round for K=5 executes as <= 3 jitted program
    dispatches (vmapped local scan, shared predict, fused mutual step) —
    no per-client Python loop over batches."""
    vn = reduced()
    (tr_x, tr_y), _ = make_paper_datasets(image_size=vn.image_size,
                                          n_train=600, n_test=40)
    fc = FederatedConfig(method="dml", n_clients=5, rounds=2,
                         local_epochs=2, batch_size=16)
    tr = FederatedTrainer(vn, fc, tr_x, tr_y)
    tr.run()
    for r in range(fc.rounds):
        progs = [p for rr, p in tr.dispatch_log if rr == r]
        assert len(progs) <= 3, progs
        assert progs.count("local_scan") == 1
        assert progs.count("mutual_scan") == 1


def test_comm_accounting_scales_with_mutual_epochs():
    """Sharing happens EVERY mutual epoch: comm_bytes = E * 2K * B_pub * 4,
    and zero (not NameError) when mutual_epochs == 0."""
    vn = reduced()
    (tr_x, tr_y), _ = make_paper_datasets(image_size=vn.image_size,
                                          n_train=240, n_test=40)
    comm = {}
    for me in (0, 1, 3):
        fc = FederatedConfig(method="dml", n_clients=2, rounds=1,
                             local_epochs=1, batch_size=16, mutual_epochs=me)
        tr = FederatedTrainer(vn, fc, tr_x, tr_y)
        h = tr.run()
        comm[me] = h.total_comm_bytes
        assert h.rounds[0].comm_bytes == h.total_comm_bytes
    assert comm[0] == 0
    assert comm[3] == 3 * comm[1] > 0


def test_partial_participation_masks_and_scales_comm():
    """M < K: absentees' params/opt are bitwise-untouched, they are excluded
    from the Eq.-2 average, and comm_bytes scale with M (all 3 methods)."""
    vn = reduced()
    (tr_x, tr_y), _ = make_paper_datasets(image_size=vn.image_size,
                                          n_train=300, n_test=40)
    for method in ("dml", "fedavg", "async"):
        comm = {}
        for m in (0, 2):
            fc = FederatedConfig(method=method, n_clients=4, rounds=1,
                                 local_epochs=1, batch_size=16,
                                 participation=m, min_round=0, delta=1,
                                 seed=3)
            t = FederatedTrainer(vn, fc, tr_x, tr_y)
            before = jax.tree.map(lambda x: np.asarray(x).copy(),
                                  t.client_params)
            h = t.run()
            comm[m] = h.total_comm_bytes
            if m == 2:
                part = h.rounds[0].participants
                assert len(part) == 2
                for c in (c for c in range(4) if c not in part):
                    for x, y in zip(jax.tree.leaves(before),
                                    jax.tree.leaves(t.client_params)):
                        np.testing.assert_array_equal(x[c], np.asarray(y)[c])
        assert comm[0] > 0
        assert comm[2] * 4 == comm[0] * 2, (method, comm)


def test_participation_full_equals_disabled():
    """participation=K must be the identity knob: bitwise-equal to the
    default full-participation run (and RoundLog.participants stays None)."""
    vn = reduced()
    (tr_x, tr_y), _ = make_paper_datasets(image_size=vn.image_size,
                                          n_train=240, n_test=40)
    outs = []
    for m in (0, 2):
        fc = FederatedConfig(method="dml", n_clients=2, rounds=1,
                             local_epochs=1, batch_size=16,
                             participation=m, seed=1)
        t = FederatedTrainer(vn, fc, tr_x, tr_y)
        h = t.run()
        assert h.rounds[0].participants is None
        outs.append(t.client_params)
    for x, y in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[1])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("method", ["dml", "async"])
def test_resume_bitwise_matches_uninterrupted(method, tmp_path):
    """Acceptance (checkpoint satellite): save at the round boundary,
    restore into a fresh trainer, continue — params, opt state, comm
    accounting and history all bitwise-match the uninterrupted run."""
    vn = reduced()
    (tr_x, tr_y), _ = make_paper_datasets(image_size=vn.image_size,
                                          n_train=300, n_test=40)
    fc = FederatedConfig(method=method, n_clients=2, rounds=2,
                         local_epochs=1, batch_size=16, min_round=0,
                         delta=2, seed=5)
    a = FederatedTrainer(vn, fc, tr_x, tr_y)
    a.run()
    b = FederatedTrainer(vn, fc, tr_x, tr_y)
    b.run(until=1)
    path = str(tmp_path / "fed_state")
    b.save_state(path)
    c = FederatedTrainer(vn, fc, tr_x, tr_y)
    c.restore_state(path)
    assert c.folds.remaining() == b.folds.remaining()
    c.run()
    for x, y in zip(jax.tree.leaves(a.client_params),
                    jax.tree.leaves(c.client_params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(jax.tree.leaves(a.client_opts),
                    jax.tree.leaves(c.client_opts)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(jax.tree.leaves(a.global_params),
                    jax.tree.leaves(c.global_params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert c.history.total_comm_bytes == a.history.total_comm_bytes
    assert [r.comm_bytes for r in c.history.rounds] == \
        [r.comm_bytes for r in a.history.rounds]


def test_restore_rejects_config_mismatch(tmp_path):
    vn = reduced()
    (tr_x, tr_y), _ = make_paper_datasets(image_size=vn.image_size,
                                          n_train=240, n_test=40)
    fc = FederatedConfig(method="dml", n_clients=2, rounds=1,
                         local_epochs=1, batch_size=16)
    t = FederatedTrainer(vn, fc, tr_x, tr_y)
    path = str(tmp_path / "st")
    t.save_state(path)
    other = FederatedTrainer(vn, FederatedConfig(
        method="fedavg", n_clients=2, rounds=1, local_epochs=1,
        batch_size=16), tr_x, tr_y)
    with pytest.raises(ValueError, match="checkpoint"):
        other.restore_state(path)


def test_dml_comm_orders_of_magnitude_smaller():
    """The paper's bandwidth claim on identical setups."""
    vn = reduced()
    (tr_x, tr_y), _ = make_paper_datasets(image_size=vn.image_size,
                                          n_train=240, n_test=40)
    comm = {}
    for method in ("dml", "fedavg"):
        fc = FederatedConfig(method=method, n_clients=2, rounds=1,
                             local_epochs=1, batch_size=16)
        tr = FederatedTrainer(vn, fc, tr_x, tr_y)
        tr.run()
        comm[method] = tr.history.total_comm_bytes
    assert comm["dml"] * 100 < comm["fedavg"]
