"""Algorithm 1 mechanics: FedAvg math, async schedule, fold discipline,
and one short engine round per framework."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.visionnet import reduced
from repro.core import async_fl, fedavg
from repro.core.federated import FederatedConfig, FederatedTrainer
from repro.data.federated import FoldScheduler
from repro.data.synthetic import make_paper_datasets


def test_fedavg_average_exact():
    stacked = {"w": jnp.asarray([[1.0, 2.0], [3.0, 4.0], [5.0, 6.0]])}
    out = fedavg.average_weights(stacked)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               [[3.0, 4.0]] * 3, atol=1e-7)


def test_weighted_average_matches_paper_scoring():
    stacked = {"w": jnp.asarray([[0.0], [10.0]])}
    out = fedavg.weighted_average_weights(stacked, jnp.asarray([1.0, 3.0]))
    np.testing.assert_allclose(np.asarray(out["w"]), [[7.5]] * 2, atol=1e-6)


def test_stack_unstack_roundtrip():
    trees = [{"a": jnp.full((2,), i), "b": [jnp.full((3,), -i)]}
             for i in range(4)]
    stacked = fedavg.stack_params(trees)
    back = fedavg.unstack_params(stacked, 4)
    for orig, got in zip(trees, back):
        assert jax.tree.all(jax.tree.map(
            lambda x, y: bool(jnp.all(x == y)), orig, got))


def test_async_layer_schedule():
    """Algorithm 1 lines 12-14: deep iff (i+1) % delta == 0 and i >= 5."""
    sched = [async_fl.layer_schedule(i, delta=3, min_round=5)
             for i in range(12)]
    deep_rounds = [i for i, s in enumerate(sched) if s == "deep"]
    assert deep_rounds == [5, 8, 11]


def test_async_update_weights_partial():
    stacked = {"shallow": jnp.asarray([[0.0], [2.0]]),
               "deep": jnp.asarray([[0.0], [2.0]])}
    mask = {"shallow": True, "deep": False}
    avg = fedavg.average_weights(stacked)
    out = async_fl.update_weights(stacked, avg, mask, "shallow")
    np.testing.assert_allclose(np.asarray(out["shallow"]), [[1.0], [1.0]])
    np.testing.assert_allclose(np.asarray(out["deep"]), [[0.0], [2.0]])
    out_deep = async_fl.update_weights(stacked, avg, mask, "deep")
    np.testing.assert_allclose(np.asarray(out_deep["deep"]), [[1.0], [1.0]])


def test_fold_scheduler_budget():
    """Fold <- (1+K) x R + 1, disjoint, class-balanced."""
    labels = np.arange(220) % 2
    K, R = 3, 4
    fs = FoldScheduler(labels, K, R, seed=0)
    assert fs.n_folds == (1 + K) * R + 1
    seen = set()
    for _ in range(fs.n_folds):
        f = fs.pop()
        frac = labels[f].mean()
        assert 0.3 < frac < 0.7              # stratification
        assert not (set(f.tolist()) & seen)  # disjoint
        seen.update(f.tolist())
    assert len(seen) == 220
    with pytest.raises(AssertionError):
        fs.pop()


@pytest.mark.parametrize("method", ["dml", "fedavg", "async"])
def test_engine_one_round(method):
    vn = reduced()
    (tr_x, tr_y), (te_x, te_y) = make_paper_datasets(
        image_size=vn.image_size, n_train=240, n_test=80)
    fc = FederatedConfig(method=method, n_clients=2, rounds=1,
                         local_epochs=1, batch_size=16)
    tr = FederatedTrainer(vn, fc, tr_x, tr_y)
    h = tr.run()
    h = tr.evaluate(te_x, te_y)
    assert len(h.rounds) == 1
    assert len(h.client_test_acc) == 2
    assert all(np.isfinite(l) for l in h.rounds[0].client_loss)
    assert h.total_comm_bytes > 0
    if method == "fedavg":
        # vanilla FL: all clients identical after sync (paper Table II row 1)
        l0 = jax.tree.leaves(tr.client_params)[0]
        np.testing.assert_allclose(np.asarray(l0[0]), np.asarray(l0[1]),
                                   atol=1e-7)


def test_non_iid_scheduler_discipline():
    """NonIIDScheduler: same pop order/budget as Algorithm 1, skewed clients,
    balanced shared folds, full partition."""
    from repro.data.federated import NonIIDScheduler
    labels = np.arange(600) % 2
    K, R = 3, 4
    sch = NonIIDScheduler(labels, K, R, alpha=0.2, seed=0)
    assert sch.n_folds == (1 + K) * R + 1
    seen = []
    init = sch.pop()                       # global-init fold (balanced)
    assert 0.3 < labels[init].mean() < 0.7
    seen.extend(init.tolist())
    client_fracs = [[] for _ in range(K)]
    for r in range(R):
        for c in range(K):
            f = sch.pop()
            if len(f) > 5:
                client_fracs[c].append(labels[f].mean())
            seen.extend(f.tolist())
        pub = sch.pop()                    # shared fold (balanced)
        assert 0.3 < labels[pub].mean() < 0.7
        seen.extend(pub.tolist())
    assert sorted(seen) == list(range(600))       # exact partition
    means = [np.mean(fr) for fr in client_fracs if fr]
    assert max(means) - min(means) > 0.15         # visible skew
    with pytest.raises(AssertionError):
        sch.pop()


def test_engine_non_iid_round():
    """The paper's future-work setting runs end-to-end."""
    vn = reduced()
    (tr_x, tr_y), (te_x, te_y) = make_paper_datasets(
        image_size=vn.image_size, n_train=400, n_test=80)
    fc = FederatedConfig(method="dml", n_clients=2, rounds=1,
                         local_epochs=1, batch_size=8, non_iid_alpha=0.3)
    tr = FederatedTrainer(vn, fc, tr_x, tr_y)
    h = tr.run()
    h = tr.evaluate(te_x, te_y)
    assert len(h.rounds) == 1 and all(np.isfinite(h.client_test_acc))


def test_dml_comm_orders_of_magnitude_smaller():
    """The paper's bandwidth claim on identical setups."""
    vn = reduced()
    (tr_x, tr_y), _ = make_paper_datasets(image_size=vn.image_size,
                                          n_train=240, n_test=40)
    comm = {}
    for method in ("dml", "fedavg"):
        fc = FederatedConfig(method=method, n_clients=2, rounds=1,
                             local_epochs=1, batch_size=16)
        tr = FederatedTrainer(vn, fc, tr_x, tr_y)
        tr.run()
        comm[method] = tr.history.total_comm_bytes
    assert comm["dml"] * 100 < comm["fedavg"]
