"""Equivalence tests for the §Perf optimisation variants: every hillclimb
change must be loss/grad-exact (or have a quantified approximation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, st

from repro.configs import get_reduced
from repro.core import mutual
from repro.kernels import ref
from repro.models import transformer as T


def _max_tree_diff(a, b):
    return max(jax.tree.leaves(jax.tree.map(
        lambda x, y: float(jnp.max(jnp.abs(x - y))), a, b)))


@pytest.mark.parametrize("arch", ["qwen3-4b", "minitron-4b",
                                  "llava-next-mistral-7b"])
def test_chunked_ce_exact(arch):
    """chunked_ce: same loss AND same gradients as dense CE."""
    cfg = get_reduced(arch)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1),
                              (2, 40 - cfg.prefix_tokens), 0, cfg.vocab_size)
    prefix = (jax.random.normal(jax.random.PRNGKey(2),
                                (2, cfg.prefix_tokens, cfg.prefix_dim))
              if cfg.prefix_tokens else None)
    l1, m1 = T.loss_fn(params, cfg, toks, prefix, ce_impl="dense")
    l2, m2 = T.loss_fn(params, cfg, toks, prefix, ce_impl="chunked")
    assert abs(float(m1["ce"] - m2["ce"])) < 1e-5
    g1 = jax.grad(lambda p: T.loss_fn(p, cfg, toks, prefix,
                                      ce_impl="dense")[0])(params)
    g2 = jax.grad(lambda p: T.loss_fn(p, cfg, toks, prefix,
                                      ce_impl="chunked")[0])(params)
    assert _max_tree_diff(g1, g2) < 1e-5


@pytest.mark.parametrize("arch", ["jamba-1.5-large-398b", "qwen3-4b"])
def test_slot_remat_exact(arch):
    cfg = get_reduced(arch)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              cfg.vocab_size)
    g1 = jax.grad(lambda p: T.loss_fn(p, cfg, toks)[0])(params)
    g2 = jax.grad(lambda p: T.loss_fn(p, cfg, toks,
                                      slot_remat=True)[0])(params)
    assert _max_tree_diff(g1, g2) < 1e-5


@given(S=st.integers(8, 80), bk=st.sampled_from([8, 16, 64]),
       window=st.one_of(st.none(), st.integers(1, 64)),
       seed=st.integers(0, 50))
def test_xla_flash_matches_oracle(S, bk, window, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, S, 4, 16))
    k = jax.random.normal(ks[1], (1, S, 2, 16))
    v = jax.random.normal(ks[2], (1, S, 2, 16))
    a = ref.attention(q, k, v, causal=True, window=window)
    b = ref.attention_xla_flash(q, k, v, causal=True, window=window,
                                block_k=bk)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=2e-5)


def test_xla_flash_grads_match():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (1, 48, 4, 16))
    k = jax.random.normal(ks[1], (1, 48, 2, 16))
    v = jax.random.normal(ks[2], (1, 48, 2, 16))
    f1 = lambda q: jnp.sum(ref.attention(q, k, v, causal=True) ** 2)
    f2 = lambda q: jnp.sum(ref.attention_xla_flash(q, k, v, causal=True,
                                                   block_k=16) ** 2)
    np.testing.assert_allclose(np.asarray(jax.grad(f1)(q)),
                               np.asarray(jax.grad(f2)(q)),
                               atol=5e-5, rtol=5e-5)


def test_sparse_mutual_in_dml_step():
    """The sparse_k option runs end-to-end in the distributed step (CPU)."""
    from repro.core import distributed as D
    from repro.optim import AdamWConfig
    cfg = get_reduced("qwen3-4b")
    K, B, S = 2, 2, 24
    sp = D.stacked_init(jax.random.PRNGKey(0), cfg, K)
    opt = D.stacked_adamw_init(sp)
    toks = jax.random.randint(jax.random.PRNGKey(1), (K, B, S), 0,
                              cfg.vocab_size)
    pub = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                             cfg.vocab_size)
    step = jax.jit(D.make_dml_train_step(cfg, AdamWConfig(), sparse_k=16))
    sp2, opt2, m = step(sp, opt, toks, pub)
    assert np.isfinite(np.asarray(m["kld_avg"])).all()
    assert float(jnp.min(m["kld_avg"])) >= -1e-5
