"""Flash-attention Pallas kernel vs the pure-jnp oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention


def _run(B, S, T, Hq, Hkv, hd, dtype, window=None, bq=32, bk=32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, hd), dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, hd), dtype)
    want = ref.attention(q, k, v, causal=True, window=window)
    qt, kt, vt = (x.transpose(0, 2, 1, 3) for x in (q, k, v))
    got = flash_attention(qt, kt, vt, causal=True, window=window,
                          block_q=bq, block_k=bk,
                          interpret=True).transpose(0, 2, 1, 3)
    return np.asarray(want, np.float32), np.asarray(got, np.float32)


@pytest.mark.parametrize("B,S,Hq,Hkv,hd", [
    (1, 64, 2, 2, 16),     # MHA
    (2, 96, 4, 2, 32),     # GQA 2:1
    (1, 128, 8, 1, 8),     # MQA
    (2, 50, 4, 4, 64),     # ragged S (padding path)
])
def test_causal_matches_oracle(B, S, Hq, Hkv, hd):
    want, got = _run(B, S, S, Hq, Hkv, hd, jnp.float32)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [1, 8, 33, 64, 1000])
def test_sliding_window(window):
    want, got = _run(1, 64, 64, 4, 2, 16, jnp.float32, window=window)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5),
                                        (jnp.bfloat16, 3e-2)])
def test_dtypes(dtype, atol):
    want, got = _run(1, 64, 64, 2, 2, 32, dtype)
    np.testing.assert_allclose(got, want, atol=atol, rtol=atol)


@pytest.mark.parametrize("bq,bk", [(16, 16), (32, 64), (64, 32), (128, 128)])
def test_block_shapes(bq, bk):
    want, got = _run(1, 96, 96, 2, 2, 16, jnp.float32, bq=bq, bk=bk)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_first_row_attends_self_only():
    """Causal row 0 must equal v[0] exactly (softmax over one entry)."""
    _, got = _run(1, 32, 32, 2, 2, 8, jnp.float32, seed=3)
    k = jax.random.split(jax.random.PRNGKey(3), 3)
    v = jax.random.normal(k[2], (1, 32, 2, 8), jnp.float32)
    np.testing.assert_allclose(got[0, 0], np.asarray(v[0, 0]), atol=1e-6)


def test_oracle_cache_positions_ring_buffer():
    """Oracle handles out-of-order cache positions (ring-buffer decode)."""
    key = jax.random.PRNGKey(0)
    B, T, H, hd = 1, 8, 2, 16
    k = jax.random.normal(key, (B, T, H, hd))
    v = jax.random.normal(jax.random.PRNGKey(1), (B, T, H, hd))
    q = jax.random.normal(jax.random.PRNGKey(2), (B, 1, H, hd))
    pos_q = jnp.array([[9]])
    # ring layout: slots hold positions 8,9(self),2..7 with slot1 = current
    pos_k = jnp.array([[8, 9, 2, 3, 4, 5, 6, 7]])
    out = ref.attention(q, k, v, causal=True, positions_q=pos_q,
                        positions_k=pos_k)
    # equivalent ordered layout
    order = jnp.argsort(pos_k[0])
    out2 = ref.attention(q, k[:, order], v[:, order], causal=True,
                         positions_q=pos_q, positions_k=pos_k[:, order])
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), atol=1e-6)
    # window=4 must drop positions < 6
    outw = ref.attention(q, k, v, causal=True, window=4,
                         positions_q=pos_q, positions_k=pos_k)
    mask = pos_k[0] >= 6
    outm = ref.attention(q, k[:, mask], v[:, mask], causal=True,
                         positions_q=pos_q, positions_k=pos_k[:, mask])
    np.testing.assert_allclose(np.asarray(outw), np.asarray(outm), atol=1e-6)


# ---------------------------------------------------------------------------
# backward: the custom VJP vs jax.grad of the oracle

def _grad_pair(B, S, Hq, Hkv, hd, window, bq, bk, seed=0):
    """(dq, dk, dv) from jax.grad of the oracle and of the flash kernel."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, S, Hq, hd))
    k = jax.random.normal(ks[1], (B, S, Hkv, hd))
    v = jax.random.normal(ks[2], (B, S, Hkv, hd))
    co = jax.random.normal(ks[3], (B, S, Hq, hd))

    def loss_ref(q, k, v):
        return jnp.sum(ref.attention(q, k, v, causal=True, window=window) * co)

    def loss_flash(q, k, v):
        qt, kt, vt = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
        o = flash_attention(qt, kt, vt, causal=True, window=window,
                            block_q=bq, block_k=bk, interpret=True)
        return jnp.sum(o.transpose(0, 2, 1, 3) * co)

    want = jax.grad(loss_ref, (0, 1, 2))(q, k, v)
    got = jax.grad(loss_flash, (0, 1, 2))(q, k, v)
    return want, got


@pytest.mark.parametrize("B,S,Hq,Hkv,hd,window,bq,bk", [
    (1, 64, 2, 2, 16, None, 32, 32),    # MHA, causal
    (2, 96, 4, 2, 32, None, 32, 32),    # GQA 2:1
    (1, 64, 8, 1, 8, 8, 32, 32),        # MQA + window
    (2, 50, 4, 4, 16, None, 32, 32),    # ragged S (padding path)
    (1, 64, 4, 2, 16, 1, 32, 32),       # window = 1
])
def test_backward_matches_oracle_grads(B, S, Hq, Hkv, hd, window, bq, bk):
    want, got = _grad_pair(B, S, Hq, Hkv, hd, window, bq, bk)
    for name, a, b in zip("qkv", want, got):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   atol=2e-4, rtol=2e-4, err_msg=f"d{name}")


def test_grad_through_ops_attention_interpret():
    """ops.attention(impl='interpret') is differentiable end to end — the
    path training steps take now that there is no grad-time downgrade."""
    from repro.kernels import ops
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 32, 2, 8))

    def loss(impl):
        return lambda x: jnp.sum(
            ops.attention(x, x, x, causal=True, impl=impl) ** 2)

    g_ref = jax.grad(loss("ref"))(q)
    g_int = jax.grad(loss("interpret"))(q)
    np.testing.assert_allclose(np.asarray(g_int), np.asarray(g_ref),
                               atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# blockwise sliding-window liveness: block-skip condition vs a dense mask

def _dense_window_oracle(q, k, v, window):
    """Explicit O(S·T) masked-softmax oracle — a dense elementwise mask,
    independent of both the kernel's block-liveness math and ref.attention's
    position plumbing."""
    B, S, Hq, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qf = q.reshape(B, S, Hkv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bskgh,btkh->bksgt", qf,
                   k.astype(jnp.float32)) * hd ** -0.5
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = (kpos <= qpos) & (qpos - kpos < window)
    s = jnp.where(mask[None, None, :, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bksgt,btkh->bskgh", p, v.astype(jnp.float32))
    return out.reshape(B, S, Hq, hd)


def _window_case(S, window, bq, bk, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (1, S, 2, 8))
    k = jax.random.normal(ks[1], (1, S, 2, 8))
    v = jax.random.normal(ks[2], (1, S, 2, 8))
    co = jax.random.normal(ks[3], (1, S, 2, 8))

    def loss_dense(q, k, v):
        return jnp.sum(_dense_window_oracle(q, k, v, window) * co)

    def loss_flash(q, k, v):
        qt, kt, vt = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
        o = flash_attention(qt, kt, vt, causal=True, window=window,
                            block_q=bq, block_k=bk, interpret=True)
        return jnp.sum(o.transpose(0, 2, 1, 3) * co)

    np.testing.assert_allclose(np.asarray(loss_flash(q, k, v)),
                               np.asarray(loss_dense(q, k, v)),
                               atol=1e-3, rtol=1e-5)
    gd = jax.grad(loss_dense, (0, 1, 2))(q, k, v)
    gf = jax.grad(loss_flash, (0, 1, 2))(q, k, v)
    for name, a, b in zip("qkv", gd, gf):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   atol=2e-4, rtol=2e-4, err_msg=f"d{name}")


@pytest.mark.parametrize("S,window,bq,bk", [
    (48, 1, 16, 16),     # window = 1: diagonal only
    (48, 48, 16, 16),    # window = seq_len: degenerates to plain causal
    (48, 33, 16, 16),    # window % block != 0 (block-skip straddles blocks)
    (40, 7, 16, 8),      # window < block, ragged S, asymmetric blocks
    (48, 17, 8, 32),     # bq < window < bk
])
def test_window_liveness_boundaries_fwd_bwd(S, window, bq, bk):
    """The `q_start - (k_start + bk - 1) < window` block-skip must be
    exactly the dense per-element mask at every boundary, forward and
    backward — a wrongly skipped live block would corrupt both."""
    _window_case(S, window, bq, bk)


@given(S=st.integers(4, 48), window=st.integers(1, 56),
       bq=st.sampled_from([8, 16, 32]), bk=st.sampled_from([8, 16, 32]),
       seed=st.integers(0, 20))
def test_property_window_liveness(S, window, bq, bk, seed):
    """Property: blockwise liveness + per-element masking == dense mask for
    ANY (S, window, block) combination, forward and backward."""
    _window_case(S, window, bq, bk, seed=seed)
