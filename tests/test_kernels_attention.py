"""Flash-attention Pallas kernel vs the pure-jnp oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention


def _run(B, S, T, Hq, Hkv, hd, dtype, window=None, bq=32, bk=32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, hd), dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, hd), dtype)
    want = ref.attention(q, k, v, causal=True, window=window)
    qt, kt, vt = (x.transpose(0, 2, 1, 3) for x in (q, k, v))
    got = flash_attention(qt, kt, vt, causal=True, window=window,
                          block_q=bq, block_k=bk,
                          interpret=True).transpose(0, 2, 1, 3)
    return np.asarray(want, np.float32), np.asarray(got, np.float32)


@pytest.mark.parametrize("B,S,Hq,Hkv,hd", [
    (1, 64, 2, 2, 16),     # MHA
    (2, 96, 4, 2, 32),     # GQA 2:1
    (1, 128, 8, 1, 8),     # MQA
    (2, 50, 4, 4, 64),     # ragged S (padding path)
])
def test_causal_matches_oracle(B, S, Hq, Hkv, hd):
    want, got = _run(B, S, S, Hq, Hkv, hd, jnp.float32)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [1, 8, 33, 64, 1000])
def test_sliding_window(window):
    want, got = _run(1, 64, 64, 4, 2, 16, jnp.float32, window=window)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5),
                                        (jnp.bfloat16, 3e-2)])
def test_dtypes(dtype, atol):
    want, got = _run(1, 64, 64, 2, 2, 32, dtype)
    np.testing.assert_allclose(got, want, atol=atol, rtol=atol)


@pytest.mark.parametrize("bq,bk", [(16, 16), (32, 64), (64, 32), (128, 128)])
def test_block_shapes(bq, bk):
    want, got = _run(1, 96, 96, 2, 2, 16, jnp.float32, bq=bq, bk=bk)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_first_row_attends_self_only():
    """Causal row 0 must equal v[0] exactly (softmax over one entry)."""
    _, got = _run(1, 32, 32, 2, 2, 8, jnp.float32, seed=3)
    k = jax.random.split(jax.random.PRNGKey(3), 3)
    v = jax.random.normal(k[2], (1, 32, 2, 8), jnp.float32)
    np.testing.assert_allclose(got[0, 0], np.asarray(v[0, 0]), atol=1e-6)


def test_oracle_cache_positions_ring_buffer():
    """Oracle handles out-of-order cache positions (ring-buffer decode)."""
    key = jax.random.PRNGKey(0)
    B, T, H, hd = 1, 8, 2, 16
    k = jax.random.normal(key, (B, T, H, hd))
    v = jax.random.normal(jax.random.PRNGKey(1), (B, T, H, hd))
    q = jax.random.normal(jax.random.PRNGKey(2), (B, 1, H, hd))
    pos_q = jnp.array([[9]])
    # ring layout: slots hold positions 8,9(self),2..7 with slot1 = current
    pos_k = jnp.array([[8, 9, 2, 3, 4, 5, 6, 7]])
    out = ref.attention(q, k, v, causal=True, positions_q=pos_q,
                        positions_k=pos_k)
    # equivalent ordered layout
    order = jnp.argsort(pos_k[0])
    out2 = ref.attention(q, k[:, order], v[:, order], causal=True,
                         positions_q=pos_q, positions_k=pos_k[:, order])
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), atol=1e-6)
    # window=4 must drop positions < 6
    outw = ref.attention(q, k, v, causal=True, window=4,
                         positions_q=pos_q, positions_k=pos_k)
    mask = pos_k[0] >= 6
    outm = ref.attention(q, k[:, mask], v[:, mask], causal=True,
                         positions_q=pos_q, positions_k=pos_k[:, mask])
    np.testing.assert_allclose(np.asarray(outw), np.asarray(outm), atol=1e-6)
