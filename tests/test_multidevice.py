"""Device-sharded federated rounds: sharded == unsharded BITWISE.

Runs on fake CPU host devices (tests/conftest.py sets
``--xla_force_host_platform_device_count=8`` when this module/marker is
selected).  The acceptance bar is exact float equality: a round executed
with whole clients sharded over a ``clients`` mesh — one all-gather of
public-fold predictions as the only collective — must reproduce the
single-device engine's params, opt state, scores, and comm accounting
bit for bit, for all three frameworks and under partial participation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.visionnet import reduced
from repro.core import stacking
from repro.core.federated import FederatedConfig, FederatedTrainer
from repro.data.synthetic import make_paper_datasets

pytestmark = pytest.mark.multidevice


def _need(n):
    if len(jax.devices()) < n:
        pytest.skip(f"needs {n} devices, have {len(jax.devices())}")


def _mesh(n):
    from repro.launch.mesh import make_client_mesh
    _need(n)
    return make_client_mesh(n)


def _data(n_train=600, n_test=80):
    vn = reduced()
    return vn, make_paper_datasets(image_size=vn.image_size,
                                   n_train=n_train, n_test=n_test)


def _run(vn, data, mesh, method, K=4, rounds=2, participation=0, seed=3):
    (tr_x, tr_y), (te_x, te_y) = data
    fc = FederatedConfig(method=method, n_clients=K, rounds=rounds,
                         local_epochs=1, batch_size=16, min_round=0,
                         delta=2, participation=participation, seed=seed)
    t = FederatedTrainer(vn, fc, tr_x, tr_y, mesh=mesh)
    t.run()
    t.evaluate(te_x, te_y)
    return t


def _assert_bitwise(a, b):
    """Full engine-state equality: params, opts, global model, history."""
    for x, y in zip(jax.tree.leaves(a.client_params),
                    jax.tree.leaves(b.client_params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(jax.tree.leaves(a.client_opts),
                    jax.tree.leaves(b.client_opts)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(jax.tree.leaves(a.global_params),
                    jax.tree.leaves(b.global_params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert [r.comm_bytes for r in a.history.rounds] == \
        [r.comm_bytes for r in b.history.rounds]
    assert a.history.total_comm_bytes == b.history.total_comm_bytes
    for ra, rb in zip(a.history.rounds, b.history.rounds):
        assert ra.client_loss == rb.client_loss
        assert ra.kl_loss == rb.kl_loss
        assert ra.participants == rb.participants
    assert a.history.client_test_acc == b.history.client_test_acc
    assert a.history.global_test_acc == b.history.global_test_acc


@pytest.mark.parametrize("method", ["dml", "fedavg", "async"])
def test_sharded_round_bitwise_parity(method):
    """Acceptance: a 4-client round on a clients=4 mesh is bit-identical
    to the single-device engine — params, scores, comm dict."""
    mesh = _mesh(4)
    vn, data = _data()
    a = _run(vn, data, None, method)
    b = _run(vn, data, mesh, method)
    _assert_bitwise(a, b)


@pytest.mark.parametrize("method", ["dml", "fedavg", "async"])
def test_sharded_partial_participation_parity(method):
    """M < K: masking, comm scaling and absentee freezing survive the
    mesh bitwise for all 3 methods."""
    mesh = _mesh(4)
    vn, data = _data()
    a = _run(vn, data, None, method, participation=2)
    b = _run(vn, data, mesh, method, participation=2)
    assert b.history.rounds[0].participants is not None
    _assert_bitwise(a, b)


@pytest.mark.parametrize("K,n_dev", [(5, 4), (3, 8), (6, 2)])
def test_sharded_spill_round_robin(K, n_dev):
    """K != n_devices spills clients round-robin (stacking.client_layout)
    and still matches the unsharded engine bitwise."""
    mesh = _mesh(n_dev)
    vn, data = _data()
    a = _run(vn, data, None, "dml", K=K, rounds=1)
    b = _run(vn, data, mesh, "dml", K=K, rounds=1)
    _assert_bitwise(a, b)


def test_sharded_state_is_actually_distributed():
    """The client axis really lives on the mesh after a DML round (it is
    not gathered between rounds), and the layout helpers invert."""
    mesh = _mesh(4)
    vn, ((tr_x, tr_y), _) = _data()
    fc = FederatedConfig(method="dml", n_clients=4, rounds=1,
                         local_epochs=1, batch_size=16, seed=3)
    t = FederatedTrainer(vn, fc, tr_x, tr_y, mesh=mesh)
    t.run()
    leaf = jax.tree.leaves(t.client_params)[0]
    assert len(leaf.sharding.device_set) == 4, leaf.sharding

    k_loc, k_pad = stacking.client_layout(4, 4)
    assert k_loc % stacking.CLIENT_CHUNK == 0
    send = stacking.rr_send_indices(4, 4)
    inv = stacking.rr_inverse_indices(4, 4)
    np.testing.assert_array_equal(send[inv[:4]], np.arange(4))


def test_sharded_llm_dml_step_matches_unsharded():
    """core.distributed.make_sharded_dml_step: one public-logit all-gather,
    per-client updates allclose to the unsharded fused step, absent
    clients bitwise-frozen."""
    from repro.configs import get_reduced
    from repro.core import distributed as dml
    from repro.data.synthetic import make_token_stream
    from repro.optim import AdamWConfig
    mesh = _mesh(4)
    cfg = get_reduced("qwen3-4b")
    K = 4
    # clip_norm=None: the sharded step clips per client, the unsharded
    # step per fleet — only the unclipped semantics are comparable
    opt_cfg = AdamWConfig(lr=1e-3, warmup=2, total_steps=10,
                          clip_norm=None)
    params = dml.stacked_init(jax.random.PRNGKey(0), cfg, K)
    opt = dml.stacked_adamw_init(params)
    toks = jnp.stack([jnp.asarray(make_token_stream(
        2, 33, cfg.vocab_size, seed=d)[:, :32]) for d in range(K)])
    pub = jnp.asarray(make_token_stream(2, 33, cfg.vocab_size,
                                        seed=99)[:, :32])

    ref_step = jax.jit(dml.make_dml_train_step(cfg, opt_cfg, kl_weight=1.0))
    sh_step = dml.make_sharded_dml_step(cfg, opt_cfg, mesh, K,
                                        kl_weight=1.0)
    p1, _, m1 = ref_step(params, opt, toks, pub)
    p2, o2, m2 = sh_step(params, opt, toks, pub)
    # atol = lr: AdamW's step-1 update is sign-normalised, so a near-zero
    # gradient element whose width-4 and width-2 roundings straddle zero
    # legitimately moves a full lr in opposite directions
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-3, rtol=0)
    np.testing.assert_allclose(np.asarray(m1["kld_avg"]),
                               np.asarray(m2["kld_avg"]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(m1["private_loss"]),
                               np.asarray(m2["private_loss"]), atol=1e-5)
    assert int(o2["step"]) == 1

    # M < K: the absent client's params ride through bitwise
    pm = jnp.asarray([1.0, 0.0, 1.0, 1.0])
    p3, _, _ = sh_step(params, opt, toks, pub, part_mask=pm)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p3)):
        np.testing.assert_array_equal(np.asarray(a)[1], np.asarray(b)[1])


def test_federation_mesh_bitwise_parity():
    """The unified API composes the execution backend too: a directly-built
    Federation(VisionClients(mesh=...), DML()) matches the single-device
    session bitwise (the legacy-shim mesh tests above cover fedavg/async)."""
    from repro.api import DML, Federation, VisionClients
    mesh = _mesh(4)
    vn, ((tr_x, tr_y), (te_x, te_y)) = _data()

    def run(m):
        fed = Federation(VisionClients(vn, tr_x, tr_y, n_clients=4,
                                       rounds=2, local_epochs=1,
                                       batch_size=16, seed=3, mesh=m),
                         DML())
        fed.run()
        fed.evaluate(split=(te_x, te_y))
        return fed

    a, b = run(None), run(mesh)
    for x, y in zip(jax.tree.leaves(a.population.client_params),
                    jax.tree.leaves(b.population.client_params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert a.history.total_comm_bytes == b.history.total_comm_bytes
    assert a.history.client_test_acc == b.history.client_test_acc


def test_client_mesh_requires_clients_axis():
    _need(2)
    from repro.sharding import make_mesh
    vn, ((tr_x, tr_y), _) = _data(240, 40)
    bad = make_mesh((2,), ("data",), devices=jax.devices()[:2])
    fc = FederatedConfig(method="dml", n_clients=2, rounds=1,
                         local_epochs=1, batch_size=16)
    with pytest.raises(ValueError, match="clients"):
        FederatedTrainer(vn, fc, tr_x, tr_y, mesh=bad)
