"""analysis.roofline: the shared three-term model + the report CLI."""
import json

import pytest

from repro.analysis import roofline as R
from repro.launch.mesh import V5E, HardwareSpec


# ---------------------------------------------------------------------------
# roofline_terms — the single implementation shared by dryrun + benchmarks

def test_terms_compute_bound():
    t = R.roofline_terms(flops=V5E.peak_flops_bf16, hbm_bytes=1.0)
    assert t["t_compute"] == pytest.approx(1.0)
    assert t["dominant"] == "t_compute"
    assert t["t_bound"] == pytest.approx(1.0)
    assert t["roofline_frac"] == pytest.approx(1.0)


def test_terms_memory_bound():
    t = R.roofline_terms(flops=V5E.peak_flops_bf16,   # 1 s of compute
                         hbm_bytes=4 * V5E.hbm_bandwidth)  # 4 s of HBM
    assert t["dominant"] == "t_memory"
    assert t["t_memory"] == pytest.approx(4.0)
    assert t["roofline_frac"] == pytest.approx(0.25)


def test_terms_collective_bound_and_zero():
    t = R.roofline_terms(0.0, 0.0, coll_bytes=2 * V5E.ici_bandwidth)
    assert t["dominant"] == "t_collective"
    assert t["t_collective"] == pytest.approx(2.0)
    assert t["roofline_frac"] == pytest.approx(0.0)
    z = R.roofline_terms(0.0, 0.0)
    assert z["t_bound"] == 0.0 and z["roofline_frac"] == 1.0


def test_terms_custom_hardware():
    hw = HardwareSpec(name="toy", peak_flops_bf16=100.0, hbm_bandwidth=10.0,
                      ici_bandwidth=1.0)
    t = R.roofline_terms(200.0, 50.0, 1.0, hw=hw)
    assert t["t_compute"] == pytest.approx(2.0)
    assert t["t_memory"] == pytest.approx(5.0)
    assert t["t_collective"] == pytest.approx(1.0)
    assert t["dominant"] == "t_memory"


def test_constants_single_source():
    """The module must not re-declare hardware peaks — launch.mesh owns
    them (the dedup contract)."""
    assert R.V5E is V5E


# ---------------------------------------------------------------------------
# report pipeline smoke: load -> table -> pick_hillclimb -> main

def _rec(arch="qwen3-4b", shape="train_4k", mesh="single",
         method="standard", **kw):
    base = dict(arch=arch, shape=shape, mesh=mesh, method=method,
                status="ok", flops_per_device=1e15, bytes_per_device=1e12,
                collectives={"total": 1e9, "pod_axis": 0},
                model_flops=6e14, useful_flop_ratio=0.6,
                peak_bytes=8 * 2**30)
    base.update(kw)
    rl = R.roofline_terms(base["flops_per_device"], base["bytes_per_device"],
                          base["collectives"]["total"])
    base.setdefault("t_compute", rl["t_compute"])
    base.setdefault("t_memory", rl["t_memory"])
    base.setdefault("t_collective", rl["t_collective"])
    base.setdefault("dominant", rl["dominant"])
    return base


def test_load_dedups_reruns(tmp_path):
    p = tmp_path / "dry.jsonl"
    first = _rec(useful_flop_ratio=0.1)
    second = _rec(useful_flop_ratio=0.9)
    p.write_text(json.dumps(first) + "\n" + json.dumps(second) + "\n")
    recs = R.load([str(p)])
    assert len(recs) == 1 and recs[0]["useful_flop_ratio"] == 0.9


def test_table_and_main_smoke(tmp_path, capsys):
    p = tmp_path / "dry.jsonl"
    rows = [_rec(),
            _rec(arch="mamba2-780m", shape="decode_32k",
                 flops_per_device=1e13, bytes_per_device=5e12),
            _rec(method="dml", mesh="multi",
                 collectives={"total": 5e11, "pod_axis": 1e9}),
            _rec(arch="dbrx-132b", status="fail", error="OOM")]
    p.write_text("".join(json.dumps(r) + "\n" for r in rows))
    recs = R.load([str(p)])
    out = R.table(recs)
    assert "| arch |" in out and "qwen3-4b" in out and "FAIL" in out
    picks = R.pick_hillclimb(recs)
    assert "worst_fraction" in picks and "paper_technique" in picks
    assert R.main([str(p)]) == 0
    printed = capsys.readouterr().out
    assert "Roofline" in printed and "Hillclimb picks" in printed


def test_main_no_records(tmp_path, capsys):
    assert R.main([str(tmp_path / "missing*.jsonl")]) == 1
