"""SSD Pallas kernel vs chunked oracle vs exact sequential recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, st

from repro.kernels import ref
from repro.kernels.ssd_scan import ssd_scan


def _inputs(B, S, H, P, G, N, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    return x, dt, A, Bm, Cm


def _sequential(x, dt, A, Bm, Cm):
    """Token-by-token h_t = exp(dt A) h_{t-1} + dt B x; y = C h."""
    B, S, H, P = x.shape
    G = Bm.shape[2]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2)
    Ch = jnp.repeat(Cm, rep, axis=2)
    state = jnp.zeros((B, H, P, Bm.shape[3]))
    ys = []
    for t in range(S):
        decay = jnp.exp(dt[:, t] * A)
        state = state * decay[:, :, None, None] + jnp.einsum(
            "bhn,bhp,bh->bhpn", Bh[:, t], x[:, t], dt[:, t])
        ys.append(jnp.einsum("bhn,bhpn->bhp", Ch[:, t], state))
    return jnp.stack(ys, 1), state


@pytest.mark.parametrize("B,S,H,P,G,N,chunk", [
    (1, 32, 2, 8, 1, 8, 8),
    (2, 48, 4, 16, 2, 8, 16),
    (1, 50, 4, 16, 2, 8, 16),   # ragged (padding path)
    (1, 16, 2, 8, 2, 4, 16),    # single chunk
])
def test_kernel_vs_oracle_vs_sequential(B, S, H, P, G, N, chunk):
    x, dt, A, Bm, Cm = _inputs(B, S, H, P, G, N)
    y_ref, s_ref = ref.ssd(x, dt, A, Bm, Cm, chunk=chunk)
    y_seq, s_seq = _sequential(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_seq),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s_ref), np.asarray(s_seq),
                               atol=1e-4, rtol=1e-4)
    y_k, s_k = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 1e-4),
                                        (jnp.bfloat16, 1e-1)])
def test_dtypes(dtype, atol):
    x, dt, A, Bm, Cm = _inputs(1, 32, 2, 8, 1, 8)
    x = x.astype(dtype)
    y_ref, _ = ref.ssd(x, dt, A, Bm, Cm, chunk=16)
    y_k, _ = ssd_scan(x, dt, A, Bm, Cm, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=atol, rtol=atol)


def test_initial_state_continuation():
    """ssd(x) over [0:S] == ssd over [0:k] then [k:S] with carried state."""
    x, dt, A, Bm, Cm = _inputs(1, 40, 2, 8, 1, 8, seed=7)
    y_full, s_full = ref.ssd(x, dt, A, Bm, Cm, chunk=8)
    k = 24
    y1, s1 = ref.ssd(x[:, :k], dt[:, :k], A, Bm[:, :k], Cm[:, :k], chunk=8)
    y2, s2 = ref.ssd(x[:, k:], dt[:, k:], A, Bm[:, k:], Cm[:, k:], chunk=8,
                     initial_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               atol=1e-4, rtol=1e-4)


@given(S=st.integers(4, 40), chunk=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 100))
def test_property_chunk_invariance(S, chunk, seed):
    """The chunked algorithm must be exactly chunk-size invariant."""
    x, dt, A, Bm, Cm = _inputs(1, S, 2, 8, 1, 4, seed=seed)
    y1, s1 = ref.ssd(x, dt, A, Bm, Cm, chunk=chunk)
    y2, s2 = ref.ssd(x, dt, A, Bm, Cm, chunk=S)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# backward: the chunked custom VJP vs jax.grad of the oracle

@pytest.mark.parametrize("B,S,H,P,G,N,chunk", [
    (1, 32, 2, 8, 1, 8, 8),     # multi-chunk
    (2, 48, 4, 16, 2, 8, 16),   # groups (GQA-style B/C sharing)
    (1, 50, 4, 16, 2, 8, 16),   # chunk spill (S % chunk != 0, padding path)
    (1, 16, 2, 8, 2, 4, 16),    # single chunk
])
def test_backward_matches_oracle_grads(B, S, H, P, G, N, chunk):
    """The kernel's chunked reverse-scan backward == jax.grad of ref.ssd
    in every tensor input, including through the final-state output."""
    x, dt, A, Bm, Cm = _inputs(B, S, H, P, G, N, seed=11)
    cy = jax.random.normal(jax.random.PRNGKey(99), (B, S, H, P))
    cs = jax.random.normal(jax.random.PRNGKey(98), (B, H, P, N))

    def loss(run):
        def f(x, dt, A, Bm, Cm):
            y, s = run(x, dt, A, Bm, Cm)
            return jnp.sum(y * cy) + jnp.sum(s * cs)
        return f

    want = jax.grad(loss(lambda *a: ref.ssd(*a, chunk=chunk)),
                    (0, 1, 2, 3, 4))(x, dt, A, Bm, Cm)
    got = jax.grad(loss(lambda *a: ssd_scan(*a, chunk=chunk,
                                            interpret=True)),
                   (0, 1, 2, 3, 4))(x, dt, A, Bm, Cm)
    for name, a, b in zip(["x", "dt", "A", "B", "C"], want, got):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   atol=2e-3, rtol=2e-3, err_msg=f"d{name}")


def test_grad_through_ops_ssd_interpret():
    """ops.ssd(impl='interpret') is differentiable end to end — the path
    training steps take now that there is no grad-time downgrade."""
    from repro.kernels import ops
    x, dt, A, Bm, Cm = _inputs(1, 32, 2, 8, 1, 8, seed=5)

    def loss(impl):
        return lambda x: jnp.sum(
            ops.ssd(x, dt, A, Bm, Cm, chunk=8, impl=impl)[0] ** 2)

    g_ref = jax.grad(loss("ref"))(x)
    g_int = jax.grad(loss("interpret"))(x)
    np.testing.assert_allclose(np.asarray(g_int), np.asarray(g_ref),
                               atol=2e-3, rtol=2e-3)
