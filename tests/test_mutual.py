"""Eq. 1/2 semantics: categorical + Bernoulli mutual losses, gradient flow."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, st

from repro.core import mutual
from repro.kernels import ref


def test_terms_match_forward_kernel_semantics():
    """mutual_kl_terms(live, live) == ref.mutual_kl (values identical;
    only gradients differ)."""
    logits = jax.random.normal(jax.random.PRNGKey(0), (4, 6, 33)) * 2
    a = mutual.mutual_kl_terms(logits, logits)
    b = ref.mutual_kl(logits)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_stop_grad_semantics():
    """d(loss_i)/d(logits_j) must vanish for j != i under the federated
    semantics (received predictions are data, not differentiable)."""
    logits = jax.random.normal(jax.random.PRNGKey(1), (3, 4, 16))

    def client0_loss(lg):
        return mutual.mutual_kl_loss(lg)[0]
    g = jax.grad(client0_loss)(logits)
    assert float(jnp.max(jnp.abs(g[0]))) > 0
    np.testing.assert_allclose(np.asarray(g[1]), 0.0, atol=1e-8)
    np.testing.assert_allclose(np.asarray(g[2]), 0.0, atol=1e-8)


def test_gradient_pulls_towards_consensus():
    """A gradient step on Eq. 2 must reduce the loss (descent direction)."""
    logits = jax.random.normal(jax.random.PRNGKey(2), (3, 8, 32)) * 3

    def total(lg):
        return jnp.sum(mutual.mutual_kl_loss(lg))
    l0 = float(total(logits))
    g = jax.grad(total)(logits)
    l1 = float(total(logits - 0.1 * g))
    assert l1 < l0


@given(K=st.integers(2, 6), B=st.integers(1, 5), seed=st.integers(0, 99))
def test_bernoulli_properties(K, B, seed):
    probs = jax.random.uniform(jax.random.PRNGKey(seed), (K, B),
                               minval=0.01, maxval=0.99)
    kl = mutual.bernoulli_mutual_eval(probs)
    assert kl.shape == (K, B)
    assert (np.asarray(kl) >= -1e-6).all()
    same = jnp.broadcast_to(probs[:1], probs.shape)
    np.testing.assert_allclose(np.asarray(mutual.bernoulli_mutual_eval(same)),
                               0.0, atol=1e-6)


def test_bernoulli_loss_stop_grad():
    probs = jnp.array([[0.2, 0.9], [0.7, 0.4], [0.5, 0.5]])
    g = jax.grad(lambda p: mutual.bernoulli_mutual_loss(p)[1])(probs)
    assert float(jnp.max(jnp.abs(g[1]))) > 0
    np.testing.assert_allclose(np.asarray(g[0]), 0.0, atol=1e-8)
    np.testing.assert_allclose(np.asarray(g[2]), 0.0, atol=1e-8)


def test_sparse_topk_exact_at_full_k():
    """k = V must recover dense Eq. 2 exactly."""
    logits = jax.random.normal(jax.random.PRNGKey(5), (3, 6, 40)) * 3
    dense = mutual.mutual_kl_loss(logits)
    idx, lt = mutual.topk_predictions(logits, 40)
    sparse = mutual.sparse_mutual_kl_loss(logits, idx, lt)
    np.testing.assert_allclose(np.asarray(sparse), np.asarray(dense),
                               atol=1e-5, rtol=1e-5)


def test_sparse_topk_approx_improves_with_k():
    """The uniform-tail approximation error must shrink as k grows."""
    logits = jax.random.normal(jax.random.PRNGKey(6), (3, 8, 64)) * 4
    dense = np.asarray(mutual.mutual_kl_loss(logits))
    errs = []
    for k in (4, 16, 48, 64):
        idx, lt = mutual.topk_predictions(logits, k)
        sp = np.asarray(mutual.sparse_mutual_kl_loss(logits, idx, lt))
        errs.append(np.abs(sp - dense).max())
    assert errs[-1] < 1e-4
    assert errs[0] > errs[2] > errs[3]


def test_sparse_gradient_only_through_live():
    logits = jax.random.normal(jax.random.PRNGKey(7), (3, 4, 32))
    idx, lt = mutual.topk_predictions(logits, 8)

    def loss(lg):
        return mutual.sparse_mutual_kl_loss(lg, idx, lt)[0]
    g = jax.grad(loss)(logits)
    assert float(jnp.max(jnp.abs(g[0]))) > 0
    np.testing.assert_allclose(np.asarray(g[1]), 0.0, atol=1e-8)


def test_sparse_share_bytes():
    """The whole point: top-64 sharing beats dense by ~V/k."""
    dense_bytes = 2 * 5 * 4096 * 152064 * 4
    sparse_bytes = mutual.sparse_share_bytes(5, 4096, 64)
    assert dense_bytes / sparse_bytes > 1000


def test_temperature_softening_reduces_kl():
    """Higher temperature -> softer distributions -> smaller divergence."""
    logits = jax.random.normal(jax.random.PRNGKey(3), (3, 8, 64)) * 5
    k1 = float(jnp.mean(mutual.mutual_kl_loss(logits, temperature=1.0)))
    k4 = float(jnp.mean(mutual.mutual_kl_loss(logits, temperature=4.0)))
    assert k4 < k1


# ---------------------------------------------------------------------------
# _pair_mask invariants (partial-participation Eq.-2 averaging)


@given(K=st.integers(2, 9), m_bits=st.integers(0, 511),
       seed=st.integers(0, 100))
def test_pair_mask_properties(K, m_bits, seed):
    """For any participation pattern: zero diagonal, zero rows/cols for
    absentees, symmetric support, and participant rows summing to exactly
    1 when M >= 2 (the 1/(M-1) average)."""
    pm = np.array([(m_bits >> i) & 1 for i in range(K)], np.float32)
    W = np.asarray(mutual._pair_mask(K, jnp.asarray(pm)))
    M = int(pm.sum())
    assert W.shape == (K, K)
    np.testing.assert_allclose(np.diag(W), 0.0)
    for i in range(K):
        if pm[i] == 0:
            np.testing.assert_allclose(W[i], 0.0)
            np.testing.assert_allclose(W[:, i], 0.0)
    np.testing.assert_array_equal(W > 0, W.T > 0)
    if M >= 2:
        rows = W.sum(axis=1)
        np.testing.assert_allclose(rows[pm > 0], 1.0, atol=1e-6)


def test_pair_mask_none_equals_full():
    """part_mask=None is the all-participants mask, exactly."""
    for K in (2, 3, 5, 8):
        a = np.asarray(mutual._pair_mask(K, None))
        b = np.asarray(mutual._pair_mask(K, jnp.ones((K,))))
        np.testing.assert_array_equal(a, b)
        np.testing.assert_allclose(a, (1.0 - np.eye(K)) / max(K - 1, 1))


def test_pair_mask_single_participant_zero():
    """M <= 1: nobody has a peer — the whole mask vanishes (no division
    blow-up from the M-1 denominator)."""
    for K in (2, 4):
        for pm in (np.zeros((K,)), np.eye(K)[0]):
            W = np.asarray(mutual._pair_mask(K, jnp.asarray(pm)))
            np.testing.assert_allclose(W, 0.0)


def test_terms_vs_rectangular_matches_square():
    """mutual_kl_terms == its rectangular shard with full-fleet rows —
    the identity the device-sharded engines rely on."""
    K, B, V = 4, 5, 33
    live = jax.random.normal(jax.random.PRNGKey(3), (K, B, V)) * 2
    pm = jnp.asarray([1.0, 1.0, 0.0, 1.0])
    W = mutual._pair_mask(K, pm)
    full = mutual.mutual_kl_terms(live, live, part_mask=pm, impl="ref")
    for i in range(K):
        rows = mutual.mutual_kl_terms_vs(live[i:i + 1], live, W[i:i + 1])
        np.testing.assert_allclose(np.asarray(rows[0]),
                                   np.asarray(full[i]), atol=1e-5)


def test_bernoulli_terms_vs_rectangular_matches_square():
    K, B = 5, 7
    probs = jax.nn.sigmoid(
        jax.random.normal(jax.random.PRNGKey(4), (K, B)) * 2)
    pm = jnp.asarray([1.0, 0.0, 1.0, 1.0, 1.0])
    W = mutual._pair_mask(K, pm)
    full = mutual.bernoulli_mutual_terms(probs, probs, part_mask=pm)
    part = mutual.bernoulli_mutual_terms_vs(probs[1:3], probs, W[1:3])
    np.testing.assert_array_equal(np.asarray(full[1:3]), np.asarray(part))
