"""Unified Federation API: the strategy x population composition, bitwise
parity with the legacy trainers, checkpoint schema compatibility, sparse
top-k sharing end-to-end, and the stable public import surface."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (DML, AsyncWeights, FedAvg, Federation, HeteroClients,
                       LMClients, SparseDML, VisionClients, get_strategy,
                       make_lm_pool)
from repro.configs import get_reduced
from repro.configs.visionnet import reduced
from repro.core.federated import FederatedConfig, FederatedTrainer
from repro.core.hetero import HeteroConfig, HeteroTrainer
from repro.data.synthetic import make_paper_datasets

ARCHS2 = ("qwen3-4b", "mamba2-780m")


# ---------------------------------------------------------------------------
# fixtures

@pytest.fixture(scope="module")
def vision_data():
    vn = reduced()
    return vn, make_paper_datasets(image_size=vn.image_size,
                                   n_train=300, n_test=80)


@pytest.fixture(scope="module")
def lm_pool():
    return make_lm_pool(160, 24, 512, seed=0)


def _hetero_pop(lm_pool, archs=ARCHS2, **kw):
    data, labels = lm_pool
    base = dict(rounds=2, local_epochs=1, batch_size=2, public_batch=2,
                seed=0)
    base.update(kw)
    return HeteroClients(archs, data, labels, **base)


def _assert_tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# public surface

def test_top_level_import_contract():
    """`repro` is a real package exporting the stable API surface."""
    import repro
    assert isinstance(repro.__version__, str) and repro.__version__
    assert "Federation" in repro.__all__
    assert repro.Federation is Federation
    assert repro.DML is DML and repro.SparseDML is SparseDML
    assert repro.FedAvg is FedAvg and repro.AsyncWeights is AsyncWeights
    assert repro.VisionClients is VisionClients
    assert {n for n in repro.__all__ if not n.startswith("_")} <= \
        set(dir(repro))
    with pytest.raises(AttributeError):
        repro.no_such_symbol


def test_strategy_registry_resolves_and_filters_knobs():
    s = get_strategy("sparse-dml", k=32, kl_weight=2.0, delta=9)  # delta
    assert isinstance(s, SparseDML)                               # ignored
    assert s.sparse_k == 32 and s.kl_weight == 2.0
    a = get_strategy("async", delta=7, k=99)
    assert isinstance(a, AsyncWeights) and a.delta == 7
    with pytest.raises(ValueError, match="unknown strategy"):
        get_strategy("gossip")


# ---------------------------------------------------------------------------
# parity: Federation == legacy shims == pre-refactor engines

@pytest.mark.parametrize("method,participation", [
    ("dml", 0), ("dml", 2), ("fedavg", 0), ("async", 2)])
def test_federation_bitwise_matches_legacy_trainer(vision_data, method,
                                                   participation):
    """A directly-composed Federation(VisionClients, strategy) reproduces
    the FederatedConfig-driven legacy trainer bitwise — params, opt,
    global model, comm ledger, history, dispatch structure."""
    vn, ((tr_x, tr_y), (te_x, te_y)) = vision_data
    fc = FederatedConfig(method=method, n_clients=3, rounds=2,
                         local_epochs=1, batch_size=16, min_round=0,
                         delta=2, participation=participation, seed=3)
    legacy = FederatedTrainer(vn, fc, tr_x, tr_y)
    legacy.run()
    legacy.evaluate(te_x, te_y)

    strategy = {"dml": lambda: DML(kl_weight=fc.kl_weight,
                                   mutual_epochs=fc.mutual_epochs),
                "fedavg": FedAvg,
                "async": lambda: AsyncWeights(delta=fc.delta,
                                              min_round=fc.min_round)
                }[method]()
    fed = Federation(
        VisionClients(vn, tr_x, tr_y, n_clients=3, rounds=2,
                      local_epochs=1, batch_size=16, seed=3),
        strategy, participation=participation)
    fed.run()
    fed.evaluate(split=(te_x, te_y))

    _assert_tree_equal(legacy.client_params, fed.population.client_params)
    _assert_tree_equal(legacy.client_opts, fed.population.client_opts)
    _assert_tree_equal(legacy.global_params, fed.population.global_params)
    assert legacy.history.total_comm_bytes == fed.history.total_comm_bytes
    for ra, rb in zip(legacy.history.rounds, fed.history.rounds):
        assert ra.client_loss == rb.client_loss
        assert ra.kl_loss == rb.kl_loss
        assert ra.comm_bytes == rb.comm_bytes
        assert ra.participants == rb.participants
        assert ra.layer == rb.layer
    assert legacy.history.client_test_acc == fed.history.client_test_acc
    assert legacy.history.global_test_acc == fed.history.global_test_acc
    assert [p for _, p in legacy.dispatch_log] == \
        [p for _, p in fed.dispatch_log]


def test_federation_matches_hetero_trainer(lm_pool):
    data, labels = lm_pool
    cfg = HeteroConfig(archs=ARCHS2, rounds=2, local_epochs=1,
                       batch_size=2, public_batch=2, participation=0,
                       seed=4)
    legacy = HeteroTrainer(cfg, data, labels)
    legacy.run()
    legacy.evaluate()
    fed = Federation(_hetero_pop(lm_pool, seed=4), DML())
    fed.run()
    fed.evaluate()
    for pa, pb in zip(legacy.client_params, fed.population.client_params):
        _assert_tree_equal(pa, pb)
    for oa, ob in zip(legacy.client_opts, fed.population.client_opts):
        _assert_tree_equal(oa, ob)
    assert legacy.history.total_comm_bytes == fed.history.total_comm_bytes
    for ra, rb in zip(legacy.history.rounds, fed.history.rounds):
        assert ra.client_loss == rb.client_loss
        assert ra.public_ce == rb.public_ce
        assert ra.kl_loss == rb.kl_loss
        assert ra.participants == rb.participants
    assert legacy.history.client_eval_loss == fed.history.client_eval_loss


# ---------------------------------------------------------------------------
# checkpoint schema: legacy save_state files <-> Federation, both ways

def test_legacy_checkpoint_restores_into_federation(vision_data, tmp_path):
    vn, ((tr_x, tr_y), _) = vision_data
    fc = FederatedConfig(method="dml", n_clients=2, rounds=2,
                         local_epochs=1, batch_size=16, seed=5)
    full = FederatedTrainer(vn, fc, tr_x, tr_y)
    full.run()
    half = FederatedTrainer(vn, fc, tr_x, tr_y)
    half.run(until=1)
    path = str(tmp_path / "legacy_fed")
    half.save_state(path)

    # schema sanity: the legacy meta keys the shim always wrote
    import json
    meta = json.load(open(path + ".json"))["meta"]
    assert meta["engine"] == "federated" and meta["method"] == "dml"
    assert {"n_clients", "round", "plan_seed", "scheduler"} <= set(meta)

    fed = Federation(VisionClients(vn, tr_x, tr_y, n_clients=2, rounds=2,
                                   local_epochs=1, batch_size=16, seed=5),
                     DML())
    fed.restore_state(path)
    assert fed.round == 1
    fed.run()
    _assert_tree_equal(full.client_params, fed.population.client_params)
    _assert_tree_equal(full.client_opts, fed.population.client_opts)
    assert full.history.total_comm_bytes == fed.history.total_comm_bytes
    assert [r.comm_bytes for r in full.history.rounds] == \
        [r.comm_bytes for r in fed.history.rounds]


def test_federation_checkpoint_restores_into_legacy_shim(lm_pool, tmp_path):
    """The reverse direction: a Federation-written state resumes through
    the HeteroTrainer shim bitwise."""
    data, labels = lm_pool
    cfg = HeteroConfig(archs=ARCHS2, rounds=2, local_epochs=1,
                       batch_size=2, public_batch=2, seed=7)
    full = Federation(_hetero_pop(lm_pool, seed=7), DML())
    full.run()
    half = Federation(_hetero_pop(lm_pool, seed=7), DML())
    half.run(until=1)
    path = str(tmp_path / "fed_state")
    half.save_state(path)
    legacy = HeteroTrainer(cfg, data, labels)
    legacy.restore_state(path)
    assert legacy._round == 1
    legacy.run()
    for pa, pb in zip(full.population.client_params, legacy.client_params):
        _assert_tree_equal(pa, pb)
    assert full.history.total_comm_bytes == legacy.history.total_comm_bytes


def test_restore_rejects_strategy_mismatch(vision_data, tmp_path):
    vn, ((tr_x, tr_y), _) = vision_data
    pop = lambda: VisionClients(vn, tr_x, tr_y, n_clients=2, rounds=1,
                                local_epochs=1, batch_size=16)
    fed = Federation(pop(), DML())
    path = str(tmp_path / "st")
    fed.save_state(path)
    other = Federation(pop(), FedAvg())
    with pytest.raises(ValueError, match="checkpoint"):
        other.restore_state(path)


# ---------------------------------------------------------------------------
# sparse top-k sharing, end to end

def test_sparse_kl_to_received_matches_stacked_form():
    """Per-client sparse Eq. 2 vs received top-k sets == row i of the
    stacked ``sparse_mutual_kl_loss`` (same tail model)."""
    from repro.core.mutual import (sparse_kl_to_received,
                                   sparse_mutual_kl_loss, topk_predictions)
    rng = np.random.default_rng(2)
    K, B, V, k = 4, 5, 32, 6
    stack = jnp.asarray(rng.normal(0, 1, (K, B, V)).astype(np.float32))
    idx, logp = topk_predictions(stack, k)
    full = np.asarray(sparse_mutual_kl_loss(stack, idx, logp))  # (K,)
    for i in range(K):
        others_idx = jnp.asarray(np.delete(np.asarray(idx), i, axis=0))
        others_logp = jnp.asarray(np.delete(np.asarray(logp), i, axis=0))
        mine = np.asarray(sparse_kl_to_received(stack[i], others_idx,
                                                others_logp))   # (B,)
        np.testing.assert_allclose(mine.mean(), full[i], atol=1e-5)


def test_hetero_sparse_dml_cuts_comm(lm_pool):
    """Acceptance: SparseDML runs on a mixed-family fleet with strictly
    lower comm than dense DML — by exactly V / (2k)."""
    from repro.core.mutual import sparse_share_bytes
    k = 8
    dense = Federation(_hetero_pop(lm_pool), DML())
    hd = dense.run()
    sparse = Federation(_hetero_pop(lm_pool), SparseDML(k=k))
    hs = sparse.run()
    assert 0 < hs.total_comm_bytes < hd.total_comm_bytes
    # dense: E * 2M * N_pub * V * 4; sparse: E * 2M * N_pub * k * 8
    V = dense.population.n_classes
    assert hd.total_comm_bytes * (k * 8) == hs.total_comm_bytes * (V * 4)
    n_pub = 2 * 24                              # public_batch * seq positions
    assert hs.rounds[0].comm_bytes == sparse_share_bytes(2, n_pub, k)
    assert all(np.isfinite(x) for r in hs.rounds for x in r.kl_loss)
    assert max(hs.rounds[0].kl_loss) > 0
    # the sparse run genuinely trained different params than dense
    la = jax.tree.leaves(dense.population.client_params[0])[0]
    lb = jax.tree.leaves(sparse.population.client_params[0])[0]
    assert not np.array_equal(np.asarray(la), np.asarray(lb))


def test_vision_population_rejects_sparse(vision_data):
    vn, ((tr_x, tr_y), _) = vision_data
    pop = VisionClients(vn, tr_x, tr_y, n_clients=2, rounds=1,
                        local_epochs=1, batch_size=16)
    with pytest.raises(ValueError, match="sparse"):
        Federation(pop, SparseDML(k=4))


def test_sparse_dml_from_cli(lm_pool, capsys):
    """Acceptance: `--strategy sparse-dml` runs from launch/train.py and
    reports strictly lower comm bytes than dense DML."""
    from repro.launch import train

    def total(strategy):
        args = ["--method", "hetero", "--archs", "qwen3-4b,qwen3-4b",
                "--rounds", "1", "--batch", "2", "--seq", "16",
                "--strategy", strategy, "--sparse-k", "8"]
        assert train.main(args) == 0
        out = capsys.readouterr().out
        line = [l for l in out.splitlines()
                if l.startswith("total_comm_bytes=")][-1]
        return int(line.split("=")[1])
    dense, sparse = total("dml"), total("sparse-dml")
    assert 0 < sparse < dense


# ---------------------------------------------------------------------------
# strategy x population compatibility matrix

def test_weight_strategies_rejected_on_mixed_archs(lm_pool):
    for strat in (FedAvg(), AsyncWeights()):
        with pytest.raises(ValueError, match="undefined"):
            Federation(_hetero_pop(lm_pool), strat)


def test_fedavg_on_identical_arch_hetero_fleet_syncs(lm_pool):
    fed = Federation(_hetero_pop(lm_pool, archs=("qwen3-4b", "qwen3-4b")),
                     FedAvg())
    h = fed.run()
    p0, p1 = fed.population.client_params
    for x, y in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), atol=1e-6)
    # weight comm scales with the param count, not the public set
    assert h.total_comm_bytes == \
        2 * 2 * fed.population.params_per_client * 4 * 2   # rounds x up/down


def test_unsupported_strategy_name_rejected(lm_pool):
    class Gossip:
        name = "gossip"
    with pytest.raises(ValueError, match="does not support"):
        Federation(_hetero_pop(lm_pool), Gossip())


# ---------------------------------------------------------------------------
# evaluate(split=...) symmetry

def test_evaluate_split_contract(vision_data, lm_pool):
    vn, ((tr_x, tr_y), (te_x, te_y)) = vision_data
    vfed = Federation(VisionClients(vn, tr_x, tr_y, n_clients=2, rounds=1,
                                    local_epochs=1, batch_size=16), DML())
    vfed.run()
    with pytest.raises(ValueError, match="split"):
        vfed.evaluate()
    h = vfed.evaluate(split=(te_x, te_y))
    assert len(h.client_test_acc) == 2 and 0 <= h.global_test_acc <= 1

    hfed = Federation(_hetero_pop(lm_pool, rounds=1), DML())
    hfed.run()
    with pytest.raises(ValueError, match="held-out"):
        hfed.evaluate(split=(te_x, te_y))
    h = hfed.evaluate()
    assert len(h.client_eval_loss) == 2
    assert all(np.isfinite(x) for x in h.client_eval_loss)

    lfed = Federation(LMClients(get_reduced("qwen3-4b"), n_clients=2,
                                rounds=1, batch=2, seq=16), DML())
    lfed.run()
    with pytest.raises(ValueError, match="held-out"):
        lfed.evaluate(split=(te_x, te_y))


# ---------------------------------------------------------------------------
# the LM population (fused distributed steps behind the session layer)

@pytest.fixture(scope="module")
def lm_cfg():
    return get_reduced("qwen3-4b")


def test_lm_population_strategy_matrix(lm_cfg):
    def pop():
        return LMClients(lm_cfg, n_clients=3, rounds=2, batch=2, seq=16,
                         seed=0)
    dml = Federation(pop(), DML())
    hd = dml.run()
    dml.evaluate()
    assert all(np.isfinite(x) for x in hd.client_eval_loss)
    assert hd.total_comm_bytes > 0
    assert hd.rounds[0].participants == [0, 1, 2]

    sparse = Federation(pop(), SparseDML(k=16))
    hs = sparse.run()
    assert 0 < hs.total_comm_bytes < hd.total_comm_bytes

    fa = Federation(pop(), FedAvg())
    hf = fa.run()
    leaf = jax.tree.leaves(fa.population.client_params)[0]
    np.testing.assert_allclose(np.asarray(leaf[0], np.float32),
                               np.asarray(leaf[1], np.float32), atol=1e-6)
    assert hf.total_comm_bytes > hd.total_comm_bytes   # weights >> logits

    asy = Federation(pop(), AsyncWeights(delta=2, min_round=0))
    ha = asy.run()
    assert [r.layer for r in ha.rounds] == ["shallow", "deep"]
    assert 0 < ha.rounds[0].comm_bytes < ha.rounds[1].comm_bytes


def test_lm_population_partial_participation(lm_cfg):
    def run(m):
        fed = Federation(LMClients(lm_cfg, n_clients=3, rounds=1, batch=2,
                                   seq=16, seed=0), DML(), participation=m)
        before = jax.tree.map(lambda x: np.asarray(x).copy(),
                              fed.population.client_params)
        h = fed.run()
        return fed, before, h
    fed, before, h = run(2)
    part = h.rounds[0].participants
    assert len(part) == 2
    (absent,) = [c for c in range(3) if c not in part]
    for x, y in zip(jax.tree.leaves(before),
                    jax.tree.leaves(fed.population.client_params)):
        np.testing.assert_array_equal(x[absent], np.asarray(y)[absent])
    _, _, hf = run(0)
    assert h.total_comm_bytes * 3 == hf.total_comm_bytes * 2


def test_lm_local_phase_isolates_absentees(lm_cfg):
    """Weight strategies with M < K: participants' updates must not depend
    on the absent client's private data in ANY way — including through the
    shared global-norm gradient clip (losses are masked BEFORE the grad)."""
    from repro.data.federated import sample_participants
    part = sample_participants(3, 2, 0, 0)
    (absent,) = [c for c in range(3) if c not in part]

    class Tampered(LMClients):
        def _private_batch(self, r):
            t = super()._private_batch(r)
            return t.at[absent].set((t[absent] + 7) % self.cfg.vocab_size)

    outs = []
    for cls in (LMClients, Tampered):
        fed = Federation(cls(lm_cfg, n_clients=3, rounds=1, batch=2,
                             seq=16, seed=0), FedAvg(), participation=2)
        fed.run()
        outs.append(fed.population.client_params)
    for x, y in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[1])):
        for c in part:
            np.testing.assert_array_equal(np.asarray(x)[c],
                                          np.asarray(y)[c])


def test_lm_single_participant_skips_sharing(lm_cfg):
    """M < 2: the fused population must behave like the others — local
    training only, no public-fold descent, zero comm."""
    fed = Federation(LMClients(lm_cfg, n_clients=3, rounds=1, batch=2,
                               seq=16, seed=0), DML(), participation=1)
    before = jax.tree.map(lambda x: np.asarray(x).copy(),
                          fed.population.client_params)
    h = fed.run()
    assert h.total_comm_bytes == 0
    (lone,) = h.rounds[0].participants
    assert h.rounds[0].kl_loss == [0.0] * 3
    leaf_b = jax.tree.leaves(before)
    leaf_a = jax.tree.leaves(fed.population.client_params)
    for x, y in zip(leaf_b, leaf_a):
        for c in range(3):
            if c == lone:
                continue
            np.testing.assert_array_equal(x[c], np.asarray(y)[c])
    assert any(not np.array_equal(x[lone], np.asarray(y)[lone])
               for x, y in zip(leaf_b, leaf_a))


def test_lm_population_prefix_arch(lm_cfg):
    """Modality-frontend archs (prefix_tokens > 0) train through the LM
    population — the legacy train.py DML loop supported them, so the
    session path must too."""
    cfg = get_reduced("musicgen-medium")
    assert cfg.prefix_tokens > 0
    fed = Federation(LMClients(cfg, n_clients=2, rounds=1, batch=2, seq=16,
                               seed=0), DML())
    h = fed.run()
    assert all(np.isfinite(x) for x in h.rounds[0].client_loss)
    fed.evaluate()
    assert all(np.isfinite(x) for x in h.client_eval_loss)


def test_lm_population_mesh_rejects_non_dense(lm_cfg):
    class FakeMesh:
        axis_names = ("clients",)
    pop = LMClients(lm_cfg, n_clients=2, rounds=1, batch=2, seq=16,
                    mesh=FakeMesh())
    with pytest.raises(ValueError, match="dense dml"):
        Federation(pop, SparseDML(k=8))


def test_participants_sampler_shared_across_engines(lm_pool):
    """One sampler: the session's subsets are data.federated's, so every
    strategy/population pairing with the same (seed, round) agrees."""
    from repro.data.federated import sample_participants
    fed = Federation(_hetero_pop(lm_pool, seed=9), DML(), participation=1)
    for r in range(3):
        assert fed.participants(r) == sample_participants(2, 1, 9, r)
