"""Serving correctness: prefill + decode must reproduce the full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import transformer as tfm


def _no_drop(cfg):
    if cfg.moe is None:
        return cfg
    m = dataclasses.replace(cfg.moe,
                            capacity_factor=float(cfg.moe.n_experts) /
                            cfg.moe.top_k)
    return cfg.replace(moe=m)


@pytest.mark.parametrize("arch", [
    "qwen3-8b", "mamba2-780m", "jamba-1.5-large-398b", "dbrx-132b",
    "qwen1.5-110b", "musicgen-medium", "minitron-4b",
])
def test_prefill_decode_matches_forward(arch):
    cfg = _no_drop(get_reduced(arch)).replace(prefix_tokens=0, prefix_dim=0)
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    B, Sp, n_dec = 2, 17, 4
    total = Sp + n_dec
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, total), 0,
                              cfg.vocab_size)
    full, _ = tfm.forward(params, cfg, toks, remat=False)
    lg, cache = tfm.prefill(params, cfg, toks[:, :Sp], max_seq=total)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, Sp - 1]),
                               atol=2e-4, rtol=2e-4)
    for t in range(n_dec - 1):
        lg, cache = tfm.decode_step(params, cfg, toks[:, Sp + t: Sp + t + 1],
                                    cache, jnp.int32(Sp + t))
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full[:, Sp + t]),
                                   atol=2e-4, rtol=2e-4)


def test_sliding_window_ring_buffer_decode():
    """Decode past the window: ring cache must equal windowed full forward."""
    W = 8
    cfg = get_reduced("qwen3-8b").replace(sliding_window=W, prefix_tokens=0,
                                          prefix_dim=0)
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    B, Sp, n_dec = 1, 6, 10                    # decode well past the window
    total = Sp + n_dec
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, total), 0,
                              cfg.vocab_size)
    full, _ = tfm.forward(params, cfg, toks, remat=False)   # windowed via cfg
    lg, cache = tfm.prefill(params, cfg, toks[:, :Sp], max_seq=total)
    assert cache["slot0"]["k"].shape[2] == W   # ring is window-sized
    for t in range(n_dec - 1):
        lg, cache = tfm.decode_step(params, cfg, toks[:, Sp + t: Sp + t + 1],
                                    cache, jnp.int32(Sp + t))
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full[:, Sp + t]),
                                   atol=2e-4, rtol=2e-4)


def test_prefill_longer_than_window():
    """Prompt longer than the window: ring keeps only the trailing W keys."""
    W = 8
    cfg = get_reduced("qwen3-8b").replace(sliding_window=W, prefix_tokens=0,
                                          prefix_dim=0)
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    B, Sp = 1, 20
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, Sp + 2), 0,
                              cfg.vocab_size)
    full, _ = tfm.forward(params, cfg, toks, remat=False)
    lg, cache = tfm.prefill(params, cfg, toks[:, :Sp], max_seq=Sp + 2)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, Sp - 1]),
                               atol=2e-4, rtol=2e-4)
    lg, cache = tfm.decode_step(params, cfg, toks[:, Sp:Sp + 1], cache,
                                jnp.int32(Sp))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, Sp]),
                               atol=2e-4, rtol=2e-4)


def test_vlm_prefill_decode_with_prefix():
    cfg = get_reduced("llava-next-mistral-7b")
    params = tfm.init_model(jax.random.PRNGKey(0), cfg)
    B, Sp, n_dec = 1, 9, 3
    P = cfg.prefix_tokens
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, Sp + n_dec), 0,
                              cfg.vocab_size)
    prefix = jax.random.normal(jax.random.PRNGKey(2),
                               (B, P, cfg.prefix_dim))
    full, _ = tfm.forward(params, cfg, toks, prefix, remat=False)
    lg, cache = tfm.prefill(params, cfg, toks[:, :Sp], prefix,
                            max_seq=P + Sp + n_dec)
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(full[:, P + Sp - 1]),
                               atol=2e-4, rtol=2e-4)
    for t in range(n_dec - 1):
        lg, cache = tfm.decode_step(params, cfg, toks[:, Sp + t: Sp + t + 1],
                                    cache, jnp.int32(P + Sp + t))
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full[:, P + Sp + t]),
                                   atol=2e-4, rtol=2e-4)
