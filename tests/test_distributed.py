"""Mesh-scale client-stacked steps: DML converges the clients, baselines
sync correctly, comm accounting matches the paper's claim."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_reduced
from repro.core import distributed as D
from repro.optim import AdamWConfig

CFG = get_reduced("qwen3-4b")
OPT = AdamWConfig(lr=3e-3, warmup=2, total_steps=50, clip_norm=1.0)


def _setup(K=3, B=2, S=32, seed=0):
    key = jax.random.PRNGKey(seed)
    sp = D.stacked_init(key, CFG, K)
    opt = D.stacked_adamw_init(sp)
    toks = jax.random.randint(key, (K, B, S), 0, CFG.vocab_size)
    pub = jax.random.randint(jax.random.PRNGKey(seed + 1), (B, S), 0,
                             CFG.vocab_size)
    return sp, opt, toks, pub


def test_dml_step_metrics_finite():
    sp, opt, toks, pub = _setup()
    step = jax.jit(D.make_dml_train_step(CFG, OPT))
    sp2, opt2, m = step(sp, opt, toks, pub)
    for k in ("private_loss", "public_ce", "kld_avg"):
        assert m[k].shape == (3,)
        assert np.isfinite(np.asarray(m[k])).all(), k
    assert float(jnp.min(m["kld_avg"])) >= 0


def test_mutual_step_reduces_kld():
    """Repeated Eq.-1 steps must pull clients together (paper §V:
    'over time the clients do mimic each other')."""
    sp, opt, _, pub = _setup(seed=3)
    step = jax.jit(D.make_mutual_step(CFG, OPT, kl_weight=5.0,
                                      ce_weight=0.0))
    klds = []
    for _ in range(8):
        sp, opt, m = step(sp, opt, pub)
        klds.append(float(jnp.mean(m["kld_avg"])))
    assert klds[-1] < klds[0] * 0.8, klds


def test_fedavg_sync_equalises():
    sp, *_ = _setup(K=2)
    synced = D.fedavg_sync(sp)
    for leaf in jax.tree.leaves(synced):
        np.testing.assert_allclose(np.asarray(leaf[0], np.float32),
                                   np.asarray(leaf[1], np.float32),
                                   atol=1e-6)


def test_async_sync_shallow_only():
    sp, *_ = _setup(K=2, seed=5)
    mask = D.transformer_shallow_mask(CFG, sp)
    out = D.async_sync(sp, jnp.ones(2), mask, round_idx=0)  # shallow round
    # embed (shallow): synced
    np.testing.assert_allclose(np.asarray(out["embed"][0]),
                               np.asarray(out["embed"][1]), atol=1e-6)
    # lm_head (deep): untouched
    np.testing.assert_allclose(np.asarray(out["lm_head"]),
                               np.asarray(sp["lm_head"]), atol=1e-7)
    assert float(jnp.max(jnp.abs(out["lm_head"][0] - out["lm_head"][1]))) > 0
    # deep round syncs everything
    out_deep = D.async_sync(sp, jnp.ones(2), mask, round_idx=5)
    np.testing.assert_allclose(np.asarray(out_deep["lm_head"][0]),
                               np.asarray(out_deep["lm_head"][1]), atol=1e-6)


def test_comm_bytes_claim_at_scale():
    """At LLM scale with a modest public set, loss sharing beats weight
    sharing by orders of magnitude (the paper's central claim)."""
    cfg = get_config("dbrx-132b")
    c = D.comm_bytes(cfg, n_clients=5, public_tokens=4096)
    assert c["fedavg_round"] > 100 * c["dml_round"]


def test_local_step_clients_independent():
    """Without the mutual term, client gradients must not mix."""
    sp, opt, toks, _ = _setup(K=2, seed=7)
    step = jax.jit(D.make_local_train_step(CFG, OPT))
    # clients see identical data -> if they start identical they stay identical
    same_toks = jnp.broadcast_to(toks[:1], toks.shape)
    sp_same = jax.tree.map(lambda p: jnp.broadcast_to(p[:1], p.shape), sp)
    sp2, _, _ = step(sp_same, opt, same_toks)
    for leaf in jax.tree.leaves(sp2):
        np.testing.assert_allclose(np.asarray(leaf[0], np.float32),
                                   np.asarray(leaf[1], np.float32),
                                   atol=1e-6)
