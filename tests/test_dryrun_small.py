"""Dry-run machinery smoke test on 8 host devices (subprocess isolation so
the main test session keeps its single-device view)."""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")


def _run(arch, method="standard", kind="train"):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, os.path.join(HERE, "_dryrun_small.py"), arch,
         method, kind],
        capture_output=True, text=True, timeout=420, env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
    return out.stdout


@pytest.mark.parametrize("arch", ["qwen3-4b", "dbrx-132b", "mamba2-780m",
                                  "jamba-1.5-large-398b"])
def test_train_lowering(arch):
    _run(arch, "standard", "train")


def test_decode_lowering():
    _run("qwen3-4b", "standard", "decode")


def test_prefill_lowering():
    _run("mamba2-780m", "standard", "prefill")


def test_dml_lowering():
    out = _run("qwen3-4b", "dml", "train")
    assert "pod_axis" in out


def test_fedavg_sync_lowering():
    out = _run("qwen3-4b", "fedavg_sync", "train")
    # the weight sync must put traffic on the pod (client) axis
    val = float(out.split("pod_axis=")[1].split()[0])
    assert val > 0


# ---------------------------------------------------------------------------
# FLOP cost model (direct unit tests — no subprocess needed)

def test_train_flops_count_fwd_plus_bwd():
    """Training steps cost 6·N·D (fwd+bwd), forward-only steps 2·N·D.

    Every kernel impl now carries a custom VJP, so there is no grad-time
    downgrade and the classic ratio must be exactly 3 for identical token
    counts."""
    from repro.configs import get_config
    from repro.configs.base import ShapeConfig
    from repro.launch.dryrun import model_flops_estimate

    cfg = get_config("qwen3-4b")
    train = ShapeConfig("t", 1024, 8, "train")
    prefill = ShapeConfig("p", 1024, 8, "prefill")
    ft = model_flops_estimate(cfg, train)
    fp = model_flops_estimate(cfg, prefill)
    assert ft == pytest.approx(3.0 * fp)
    # and the absolute anchors: 6ND / 2ND
    n, d = cfg.active_param_count(), 1024 * 8
    assert ft == pytest.approx(6.0 * n * d)
    assert fp == pytest.approx(2.0 * n * d)


def test_flops_estimate_methods():
    from repro.configs import get_config
    from repro.configs.base import ShapeConfig
    from repro.launch.dryrun import model_flops_estimate

    cfg = get_config("qwen3-4b")
    shape = ShapeConfig("t", 512, 16, "train")
    n = cfg.active_param_count()
    # fedavg_sync moves no tokens
    assert model_flops_estimate(cfg, shape, "fedavg_sync") == 0.0
    # decode shapes process one token per step, forward-only
    dec = ShapeConfig("d", 512, 16, "decode")
    assert model_flops_estimate(cfg, dec) == pytest.approx(2.0 * n * 16)
    # dml = local train + mutual phase; mutual = mutual phase alone
    k = 2
    pub = max(1, 16 // (4 * k)) * 512
    base = 6.0 * n * 16 * 512
    extra = 6.0 * n * pub * k
    assert model_flops_estimate(cfg, shape, "dml") == \
        pytest.approx(base + extra)
    assert model_flops_estimate(cfg, shape, "mutual") == \
        pytest.approx(extra)
