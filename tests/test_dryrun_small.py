"""Dry-run machinery smoke test on 8 host devices (subprocess isolation so
the main test session keeps its single-device view)."""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")


def _run(arch, method="standard", kind="train"):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, os.path.join(HERE, "_dryrun_small.py"), arch,
         method, kind],
        capture_output=True, text=True, timeout=420, env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
    return out.stdout


@pytest.mark.parametrize("arch", ["qwen3-4b", "dbrx-132b", "mamba2-780m",
                                  "jamba-1.5-large-398b"])
def test_train_lowering(arch):
    _run(arch, "standard", "train")


def test_decode_lowering():
    _run("qwen3-4b", "standard", "decode")


def test_prefill_lowering():
    _run("mamba2-780m", "standard", "prefill")


def test_dml_lowering():
    out = _run("qwen3-4b", "dml", "train")
    assert "pod_axis" in out


def test_fedavg_sync_lowering():
    out = _run("qwen3-4b", "fedavg_sync", "train")
    # the weight sync must put traffic on the pod (client) axis
    val = float(out.split("pod_axis=")[1].split()[0])
    assert val > 0
