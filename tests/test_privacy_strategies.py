"""Strategy-layer contract for the privacy battery: registry routing,
DPDML/robust knob validation, exact no-op gating of the extended mutual
program, comm-cost neutrality of DP noising, and checkpoint round-trips
(bitwise resume parity, accountant state included) through Federation.
"""
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parent))
from _seeds import derive

from repro.api import (DML, DPDML, Federation, MedianDML, TrimmedDML,
                       VisionClients, get_strategy)
from repro.configs.visionnet import reduced
from repro.core.populations.lm import LMClients
from repro.core.strategies.base import STRATEGIES

CFG = reduced().replace(image_size=16)


def _pop(seed, rounds=2, **kw):
    rng = np.random.default_rng(seed)
    imgs = rng.normal(size=(240, 16, 16, 3)).astype(np.float32)
    labs = (rng.random(240) > 0.5).astype(np.float32)
    return VisionClients(CFG, imgs, labs, n_clients=3, rounds=rounds,
                         local_epochs=1, batch_size=16, seed=seed, **kw)


def _assert_tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ----------------------------------------------------------------- registry
def test_privacy_strategies_registered():
    assert {"dp-dml", "trimmed-dml", "median-dml"} <= set(STRATEGIES)


def test_get_strategy_routes_knobs():
    s = get_strategy("dp-dml", kl_weight=2.0, dp_noise_multiplier=3.0,
                     trim=4)                      # trim ignored for dp-dml
    assert isinstance(s, DPDML)
    assert s.kl_weight == 2.0 and s.dp_noise_multiplier == 3.0
    t = get_strategy("trimmed-dml", trim=2, dp_noise_multiplier=9.0)
    assert isinstance(t, TrimmedDML) and t.trim == 2
    m = get_strategy("median-dml")
    assert isinstance(m, MedianDML) and m.robust_mode == "median"
    # the shared CLI namespace must not leak into plain DML either
    assert isinstance(get_strategy("dml", dp_noise_multiplier=1.0), DML)


def test_dpdml_knob_validation():
    with pytest.raises(ValueError):
        DPDML(dp_noise_multiplier=0.0)
    with pytest.raises(ValueError):
        DPDML(dp_noise_multiplier=-1.0)
    with pytest.raises(ValueError):
        DPDML(dp_clip=0.0)
    with pytest.raises(ValueError):
        TrimmedDML(trim=-1)


def test_population_capability_gates():
    # prediction-noising makes no sense where no per-example prediction
    # payload exists: LMClients never advertised the new strategies
    for name in ("dp-dml", "trimmed-dml", "median-dml"):
        assert name not in LMClients.supported
    # and VisionClients still rejects sparse sharing
    pop = _pop(derive("gates"))
    with pytest.raises(ValueError):
        Federation(pop, get_strategy("sparse-dml", k=4))


# ----------------------------------------------------------- exact no-ops
def test_payload_recording_does_not_perturb_training():
    """record_payloads routes DML through the extended mutual program
    whose sigma=0 noise gate must be an EXACT no-op — the payload tap is
    free."""
    seed = derive("noop")
    plain = Federation(_pop(seed), DML(kl_weight=1.0, mutual_epochs=2))
    plain.run()
    tapped_pop = _pop(seed, record_payloads=True)
    tapped = Federation(tapped_pop, DML(kl_weight=1.0, mutual_epochs=2))
    tapped.run()
    _assert_tree_equal(plain.population.client_params,
                       tapped_pop.client_params)
    assert len(tapped_pop.payload_log) > 0
    assert tapped_pop.payload_log[0]["payloads"].shape[1] == 3   # (E, K, B)


def test_dp_noise_actually_changes_training():
    seed = derive("dp-bites")
    a = Federation(_pop(seed), DML(kl_weight=1.0, mutual_epochs=2))
    a.run()
    b = Federation(_pop(seed), DPDML(kl_weight=1.0, mutual_epochs=2,
                                     dp_noise_multiplier=1.0))
    b.run()
    la = np.concatenate([np.asarray(x).ravel() for x in
                         jax.tree.leaves(a.population.client_params)])
    lb = np.concatenate([np.asarray(x).ravel() for x in
                         jax.tree.leaves(b.population.client_params)])
    assert not np.allclose(la, lb)


# --------------------------------------------------------------- comm cost
def test_dp_and_robust_comm_bytes_equal_dml():
    """Noise and robust combining are free on the wire: same payload
    tensor crosses client boundaries."""
    seed = derive("comm")
    runs = {}
    for name, knobs in [("dml", {}), ("dp-dml", {"dp_noise_multiplier": 1.0}),
                        ("trimmed-dml", {"trim": 1}), ("median-dml", {})]:
        fed = Federation(_pop(seed), get_strategy(name, kl_weight=1.0,
                                                  mutual_epochs=2, **knobs))
        fed.run()
        runs[name] = fed.history.total_comm_bytes
    assert runs["dml"] > 0
    assert len(set(runs.values())) == 1, runs


# -------------------------------------------------------------- accounting
def test_federation_epsilon_monotone_in_noise():
    seed = derive("eps-mono")
    eps = []
    for sigma in (0.5, 1.0, 2.0):
        fed = Federation(_pop(seed), DPDML(dp_noise_multiplier=sigma))
        fed.run()
        eps.append(fed.strategy.epsilon())
    assert eps[0] > eps[1] > eps[2] > 0
    # and the accountant saw one release per mutual epoch per round
    assert fed.strategy.accountant.releases == 2    # 2 rounds x 1 epoch


# ------------------------------------------------------------- checkpoints
@pytest.mark.parametrize("name,knobs", [
    ("dp-dml", {"dp_noise_multiplier": 1.0, "dp_clip": 2.0}),
    ("trimmed-dml", {"trim": 1}),
])
def test_checkpoint_resume_is_bitwise(tmp_path, name, knobs):
    """Interrupt/resume through Federation.save_state must replay the
    identical noise stream and combiner: params bitwise equal to the
    uninterrupted run, accountant curve included."""
    seed = derive("ckpt", name)
    mk = lambda: get_strategy(name, kl_weight=1.0, mutual_epochs=2, **knobs)
    full = Federation(_pop(seed), mk())
    full.run()

    half = Federation(_pop(seed), mk())
    half.run(until=1)
    path = str(tmp_path / f"state_{name}")
    half.save_state(path)

    resumed = Federation(_pop(seed), mk())
    resumed.restore_state(path)
    assert resumed.round == 1
    resumed.run()
    _assert_tree_equal(full.population.client_params,
                       resumed.population.client_params)
    _assert_tree_equal(full.population.client_opts,
                       resumed.population.client_opts)
    assert full.history.total_comm_bytes == resumed.history.total_comm_bytes
    if name == "dp-dml":
        assert resumed.strategy.epsilon() == full.strategy.epsilon()
        assert resumed.strategy.accountant.releases == \
            full.strategy.accountant.releases


def test_restore_rejects_dp_knob_mismatch(tmp_path):
    seed = derive("ckpt-mismatch")
    fed = Federation(_pop(seed), DPDML(dp_noise_multiplier=1.0))
    fed.run(until=1)
    path = str(tmp_path / "dp_state")
    fed.save_state(path)
    other = Federation(_pop(seed), DPDML(dp_noise_multiplier=2.0))
    with pytest.raises(ValueError, match="dp_noise_multiplier"):
        other.restore_state(path)
