"""Byzantine robustness: the trimmed/median Eq.-2 combiners against
numpy oracles, their degenerate-participation contract (M<2 skip,
deterministic trim fallback, absentee isolation), and the end-to-end
acceptance experiment — under f = floor((K-1)/3) colluding clients,
trimmed-dml and median-dml hold within 2% of clean DML while plain DML
degrades measurably.

The e2e config (K=4, 4 rounds, kl_weight=5, class-offset +-0.3 task) was
calibrated so the margins hold across seeds 0-2; ``REPRO_TEST_SEED``
re-rolls it.
"""
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parent))
from _seeds import TEST_SEED, derive

from repro.api import (Federation, HeteroClients, VisionClients,
                       get_strategy, make_lm_pool)
from repro.configs.visionnet import reduced
from repro.core import stacking
from repro.core.mutual import (bernoulli_kl_to_target,
                               kl_to_robust_received,
                               robust_bernoulli_target,
                               robust_categorical_target,
                               robust_weighted_target)
from repro.core.strategies.base import Payload

CFG = reduced().replace(image_size=16)


# ------------------------------------------------------------ numpy oracles
def _np_trimmed(vals, t):
    s = np.sort(vals)
    t = t if len(s) - 2 * t >= 1 else 0
    s = s[t:len(s) - t or None]
    return s.mean()


@pytest.mark.parametrize("mode", ["trimmed", "median"])
def test_robust_weighted_target_matches_numpy(mode):
    rng = np.random.default_rng(derive("rwt", mode))
    K, B = 7, 11
    shared = rng.uniform(size=(K, B)).astype(np.float32)
    recv = (rng.random((5, K)) > 0.35).astype(np.float32)
    recv[recv.sum(axis=1) == 0, 0] = 1.0          # no empty receiver rows
    got = np.asarray(robust_weighted_target(jnp.asarray(shared), recv,
                                            mode, trim=1))
    for i in range(recv.shape[0]):
        live = shared[:, :][recv[i] > 0]
        for b in range(B):
            want = (np.median(live[:, b]) if mode == "median"
                    else _np_trimmed(live[:, b], 1))
            assert abs(got[i, b] - want) < 1e-5


def test_median_even_and_odd_counts():
    shared = jnp.asarray(np.array([[1.0], [2.0], [10.0], [40.0]],
                                  np.float32))
    odd = robust_weighted_target(shared, np.array([[1, 1, 1, 0]],
                                                  np.float32), "median")
    assert abs(float(odd[0, 0]) - 2.0) < 1e-6
    even = robust_weighted_target(shared, np.array([[1, 1, 1, 1]],
                                                   np.float32), "median")
    assert abs(float(even[0, 0]) - 6.0) < 1e-6    # (2 + 10) / 2


def test_trimmed_drops_the_outlier():
    shared = jnp.asarray(np.array([[0.1], [0.2], [0.3], [99.0]], np.float32))
    recv = np.ones((1, 4), np.float32)
    got = robust_weighted_target(shared, recv, "trimmed", trim=1)
    assert abs(float(got[0, 0]) - 0.25) < 1e-6    # mean of {0.2, 0.3}


def test_trim_fallback_is_deterministic_masked_mean():
    """n - 2*trim < 1 must fall back to the untrimmed masked mean, not
    silently return garbage ranks."""
    rng = np.random.default_rng(derive("fallback"))
    shared = jnp.asarray(rng.uniform(size=(5, 6)).astype(np.float32))
    recv = np.array([[1, 1, 0, 0, 0]], np.float32)      # n=2, trim=1 -> 0
    got = np.asarray(robust_weighted_target(shared, recv, "trimmed",
                                            trim=1))
    want = np.asarray(shared)[:2].mean(axis=0)
    np.testing.assert_allclose(got[0], want, rtol=1e-6)
    # and with n=1 as well (trim would eat everything twice over)
    got1 = np.asarray(robust_weighted_target(
        shared, np.array([[0, 0, 1, 0, 0]], np.float32), "trimmed", trim=2))
    np.testing.assert_allclose(got1[0], np.asarray(shared)[2], rtol=1e-6)


def test_robust_weighted_target_bad_mode_raises():
    with pytest.raises(ValueError):
        robust_weighted_target(jnp.zeros((3, 2)), np.ones((1, 3)), "mean")


def test_robust_bernoulli_target_excludes_self():
    shared = jnp.asarray(np.array([[0.9, 0.9], [0.1, 0.1], [0.2, 0.2]],
                                  np.float32))
    tgt = np.asarray(robust_bernoulli_target(shared, None, "median",
                                             trim=0))
    # client 0's target comes from clients 1, 2 only
    np.testing.assert_allclose(tgt[0], [0.15, 0.15], atol=1e-6)
    assert tgt.min() >= 1e-6 and tgt.max() <= 1 - 1e-6


def test_bernoulli_kl_to_target_zero_at_target():
    p = jnp.asarray(np.array([[0.3, 0.7]], np.float32))
    np.testing.assert_allclose(np.asarray(bernoulli_kl_to_target(p, p)),
                               0.0, atol=1e-6)
    assert float(bernoulli_kl_to_target(
        jnp.asarray([[0.9]]), jnp.asarray([[0.1]]))[0, 0]) > 0.5


@pytest.mark.parametrize("mode", ["trimmed", "median"])
def test_robust_categorical_target_resists_poison(mode):
    """With an agreeing honest majority (the regime robustness is FOR),
    one confident-wrong logit row must barely move the trimmed/median
    consensus, while it visibly drags the plain mean."""
    rng = np.random.default_rng(derive("cat", mode))
    J, B, V = 5, 3, 7
    base = 2.0 * rng.normal(size=(B, V)).astype(np.float32)
    honest = base[None] + 0.3 * rng.normal(size=(J, B, V)).astype(np.float32)
    poisoned = honest.copy()
    poisoned[0] = 0.0
    poisoned[0, :, 0] = 40.0                       # one colluder, class 0
    clean_t = np.asarray(robust_categorical_target(jnp.asarray(honest),
                                                   mode, 1))
    pois_t = np.asarray(robust_categorical_target(jnp.asarray(poisoned),
                                                  mode, 1))
    mean_t = jax.nn.softmax(jnp.asarray(poisoned), axis=-1).mean(axis=0)
    assert np.abs(pois_t - clean_t).max() < 0.12
    assert float(np.abs(np.asarray(mean_t) - clean_t).max()) > 0.15
    np.testing.assert_allclose(pois_t.sum(axis=-1), 1.0, rtol=1e-5)
    # and the per-client robust KL consumes it finitely
    kl = kl_to_robust_received(jnp.asarray(honest[0]),
                               jnp.asarray(poisoned), mode, trim=1)
    assert np.all(np.isfinite(np.asarray(kl))) and kl.shape == (B,)


# -------------------------------------------------- degenerate participation
def _vision_pop(seed, K=4, rounds=2, **kw):
    rng = np.random.default_rng(seed)
    imgs = rng.normal(size=(240, 16, 16, 3)).astype(np.float32)
    labs = (rng.random(240) > 0.5).astype(np.float32)
    return VisionClients(CFG, imgs, labs, n_clients=K, rounds=rounds,
                         local_epochs=1, batch_size=16, seed=seed, **kw)


def test_single_participant_round_skips_mutual():
    pop = _vision_pop(derive("m2skip"))
    pop.begin_round(0)
    part = [1]
    pm = pop.part_mask(part)
    pop.local_phase(0, part, pm)
    out = pop.mutual_phase(0, part, pm, Payload("predictions",
                                                pop.public_payload(0)),
                           kl_weight=1.0, mutual_epochs=2,
                           robust=("trimmed", 1))
    assert out["ran"] is False


def test_absent_byzantine_client_is_isolated():
    """A poisoned client that does not participate must not perturb the
    honest clients AT ALL — their parameters stay bitwise identical to a
    run with no Byzantine client configured."""
    seed = derive("absentee")
    part = [0, 1, 2]                               # client 3 sits out

    def run(byz):
        pop = _vision_pop(seed, byzantine=byz)
        pop.begin_round(0)
        pm = pop.part_mask(part)
        pop.local_phase(0, part, pm)
        pop.mutual_phase(0, part, pm, Payload("predictions",
                                              pop.public_payload(0)),
                         kl_weight=1.0, mutual_epochs=2,
                         robust=("trimmed", 1))
        return pop.client_params

    clean = run(None)
    attacked = run({3: "collude"})
    for c in part:
        a = jax.tree.leaves(stacking.client_slice(clean, c))
        b = jax.tree.leaves(stacking.client_slice(attacked, c))
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_byzantine_constructor_validation():
    with pytest.raises(ValueError):
        _vision_pop(0, byzantine={9: "collude"})
    with pytest.raises(ValueError):
        _vision_pop(0, byzantine={0: "firehose"})


# ----------------------------------------------------------- e2e acceptance
def _byz_experiment(seed):
    """Calibrated end-to-end attack: K=4 clients on a +-0.3 class-offset
    Gaussian task, client 3 colluding (confident-wrong payloads),
    accuracy measured over the HONEST clients only."""
    K, R, kl, me, le, off, lr = 4, 4, 5.0, 3, 2, 0.3, 0.03
    rng = np.random.default_rng(seed)

    def make_xy(n):
        y = (rng.random(n) > 0.5).astype(np.float32)
        x = rng.normal(size=(n, 16, 16, 3)).astype(np.float32)
        x += (y * 2 - 1)[:, None, None, None] * off
        return x, y

    imgs, labs = make_xy(420)
    test, tlab = make_xy(300)
    byz = {K - 1: "collude"}

    def run(name, attacked, **kw):
        pop = VisionClients(CFG, imgs, labs, n_clients=K, rounds=R,
                            local_epochs=le, batch_size=16, seed=seed,
                            lr=lr, byzantine=byz if attacked else None)
        fed = Federation(pop, get_strategy(name, kl_weight=kl,
                                           mutual_epochs=me, **kw))
        fed.run()
        h = fed.evaluate(split=(test, tlab))
        return float(np.mean([a for c, a in enumerate(h.client_test_acc)
                              if c != K - 1]))

    return {"clean": run("dml", False),
            "poisoned": run("dml", True),
            "trimmed": run("trimmed-dml", True, trim=1),
            "median": run("median-dml", True)}


def test_robust_combiners_survive_collusion():
    acc = _byz_experiment(TEST_SEED)
    # plain DML collapses under one colluder in four...
    assert acc["poisoned"] <= acc["clean"] - 0.25, acc
    # ...while the robust variants hold the ISSUE's 2% band
    assert acc["trimmed"] >= acc["clean"] - 0.02, acc
    assert acc["median"] >= acc["clean"] - 0.02, acc


# ------------------------------------------------------------- hetero smoke
def test_hetero_robust_and_byzantine_run():
    data, labels = make_lm_pool(160, 24, 512, seed=derive("het"))
    pop = HeteroClients(("qwen3-4b", "mamba2-780m", "qwen3-4b"), data,
                        labels, rounds=2, local_epochs=1, batch_size=2,
                        public_batch=2, seed=0,
                        byzantine={2: "sign-flip"})
    fed = Federation(pop, get_strategy("median-dml", kl_weight=1.0))
    hist = fed.run()
    assert len(hist.rounds) == 2
    for r in hist.rounds:
        if r.public_ce:
            assert np.all(np.isfinite(r.public_ce))


def test_hetero_lm_label_flip_rejected():
    data, labels = make_lm_pool(80, 24, 512, seed=0)
    with pytest.raises(ValueError):
        HeteroClients(("qwen3-4b", "mamba2-780m"), data, labels,
                      rounds=2, byzantine={0: "label-flip"})
