"""Accountant correctness: the Rényi (ε, δ) accountant against the
closed-form single-release Gaussian bound (to 1e-6, per the acceptance
bar), against a brute-force numeric alpha-grid oracle for compositions,
and its inverse (``calibrate_noise``) and checkpoint round-trip.
"""
import math

import pytest

from repro.privacy import RDPAccountant, calibrate_noise, gaussian_epsilon


# ---------------------------------------------------------------- closed form
@pytest.mark.parametrize("sigma", [0.5, 1.0, 2.0, 4.7, 10.0])
@pytest.mark.parametrize("delta", [1e-5, 1e-6])
def test_single_release_matches_closed_form(sigma, delta):
    acc = RDPAccountant()
    acc.step(sigma)
    assert abs(acc.epsilon(delta) - gaussian_epsilon(sigma, delta)) < 1e-6


def test_gaussian_epsilon_closed_form_value():
    # 1/(2σ²) + sqrt(2 log(1/δ))/σ, written out independently
    sigma, delta = 1.3, 1e-5
    expect = 1 / (2 * 1.3 ** 2) + math.sqrt(2 * math.log(1e5)) / 1.3
    assert abs(gaussian_epsilon(sigma, delta) - expect) < 1e-12


def _grid_oracle(sigmas, delta):
    """Numeric RDP-to-DP conversion over a dense alpha grid: for the
    composed curve eps_rdp(a) = a * S, eps = min_a a*S + log(1/δ)/(a-1)."""
    s = sum(1.0 / (2 * x * x) for x in sigmas)
    alphas = [1.0 + i * 1e-4 for i in range(1, 4_000_000, 37)]
    return min(a * s + math.log(1 / delta) / (a - 1) for a in alphas)


@pytest.mark.parametrize("sigmas", [
    [1.0], [2.0, 2.0, 2.0], [0.8, 1.7, 3.1, 3.1, 5.0]])
def test_composition_matches_numeric_alpha_grid(sigmas):
    delta = 1e-5
    acc = RDPAccountant()
    for s in sigmas:
        acc.step(s)
    # the grid oracle can only be >= the analytic minimum, and close to it
    oracle = _grid_oracle(sigmas, delta)
    assert acc.epsilon(delta) <= oracle + 1e-9
    assert abs(acc.epsilon(delta) - oracle) < 1e-4


def test_releases_argument_is_plain_composition():
    a, b = RDPAccountant(), RDPAccountant()
    a.step(1.5, releases=7)
    for _ in range(7):
        b.step(1.5)
    assert a.epsilon(1e-5) == b.epsilon(1e-5)
    assert a.releases == b.releases == 7


# ---------------------------------------------------------------- monotonicity
def test_epsilon_strictly_decreasing_in_sigma():
    delta = 1e-5
    eps = [gaussian_epsilon(s, delta) for s in (0.5, 1.0, 2.0, 4.0, 8.0)]
    assert all(e1 > e2 for e1, e2 in zip(eps, eps[1:]))


def test_epsilon_monotone_in_releases():
    acc = RDPAccountant()
    prev = 0.0
    for _ in range(5):
        acc.step(2.0)
        cur = acc.epsilon(1e-5)
        assert cur > prev
        prev = cur


# ---------------------------------------------------------------- calibration
@pytest.mark.parametrize("target,releases", [(1.0, 1), (2.5, 12), (8.0, 40)])
def test_calibrate_noise_is_inverse(target, releases):
    delta = 1e-5
    sigma = calibrate_noise(target, delta, releases)
    acc = RDPAccountant()
    acc.step(sigma, releases=releases)
    eps = acc.epsilon(delta)
    assert eps <= target + 1e-6          # guarantee holds
    assert eps > target * (1 - 1e-6)     # and is tight, not slack


# ---------------------------------------------------------------- state & args
def test_state_round_trip():
    acc = RDPAccountant()
    acc.step(1.1, releases=3)
    acc.step(2.2, releases=5)
    fresh = RDPAccountant()
    fresh.load_state(acc.state())
    assert fresh.epsilon(1e-5) == acc.epsilon(1e-5)
    assert fresh.releases == acc.releases
    assert fresh.state() == acc.state()


def test_bad_arguments_raise():
    acc = RDPAccountant()
    with pytest.raises(ValueError):
        acc.step(0.0)
    with pytest.raises(ValueError):
        acc.step(-1.0)
    with pytest.raises(ValueError):
        acc.epsilon(0.0)
    with pytest.raises(ValueError):
        gaussian_epsilon(1.0, 1.5)
    with pytest.raises(ValueError):
        calibrate_noise(-1.0, 1e-5, 3)
    with pytest.raises(ValueError):
        calibrate_noise(1.0, 1e-5, 0)
    assert gaussian_epsilon(0.0, 1e-5) == math.inf


def test_empty_accountant_is_free():
    assert RDPAccountant().epsilon(1e-5) == 0.0
