"""Shared pytest harness: multi-device CPU testing, one seed knob, one
hypothesis profile.

Sharding tests need several XLA devices, which a CPU-only CI host fakes
via ``--xla_force_host_platform_device_count`` — but that flag must be in
the environment BEFORE jax initialises, so it cannot be a normal fixture.
This conftest sets it at import time (conftest imports precede all test
modules and their ``import jax``) whenever a multi-device run is
requested:

  python -m pytest -m multidevice            # the CI job
  python -m pytest tests/test_multidevice.py
  REPRO_HOST_DEVICES=8 python -m pytest ...  # explicit device count

The default tier-1 run stays single-device (the flag also splits the CPU
between fake devices, which would slow every other test); ``multidevice``
-marked tests are then skipped.

Seeding: every randomised suite derives its seeds from the single
``REPRO_TEST_SEED`` env knob through ``tests/_seeds.py`` — one variable
re-rolls the whole battery (attack probes included) without editing any
file.  Property tests share ONE hypothesis profile registered here
(deadline=None — CI machines jitter; example budget via
``REPRO_HYPOTHESIS_EXAMPLES``; derandomized for run-to-run stability)
instead of per-file ``@settings``.
"""
import os
import sys

_N = os.environ.get("REPRO_HOST_DEVICES", "")
if not _N and any("multidevice" in str(a) for a in sys.argv):
    _N = "8"
if _N and "jax" not in sys.modules:
    _flag = f"--xla_force_host_platform_device_count={_N}"
    os.environ["XLA_FLAGS"] = " ".join(
        x for x in (os.environ.get("XLA_FLAGS", ""), _flag) if x)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "multidevice: needs multiple (fake) XLA host devices; run with "
        "`pytest -m multidevice` (conftest then sets XLA_FLAGS) or set "
        "REPRO_HOST_DEVICES=N")
    try:
        from hypothesis import settings
    except ImportError:            # optional dep — see _hypothesis_compat
        return
    settings.register_profile(
        "repro",
        deadline=None,
        max_examples=int(os.environ.get("REPRO_HYPOTHESIS_EXAMPLES", "15")),
        derandomize=True,
    )
    settings.load_profile("repro")


def pytest_collection_modifyitems(config, items):
    import pytest
    n_devices = None
    for item in items:
        if item.get_closest_marker("multidevice") is None:
            continue
        if n_devices is None:
            import jax
            n_devices = len(jax.devices())
        if n_devices < 4:
            item.add_marker(pytest.mark.skip(
                reason=f"needs >= 4 XLA host devices, have {n_devices} "
                       "(run with -m multidevice)"))
