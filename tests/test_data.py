"""Data substrate: generators, sharding, fold discipline, determinism."""
import numpy as np
import pytest
from _hypothesis_compat import given, st

from repro.data import federated as fd
from repro.data import synthetic as syn


def test_image_dataset_learnable_and_balanced():
    x, y = syn.make_image_dataset(200, image_size=32, seed=0)
    assert x.shape == (200, 32, 32, 3) and x.dtype == np.float32
    assert x.min() >= 0 and x.max() <= 1
    assert abs(y.mean() - 0.5) < 0.05
    # the class signal exists: lower-center region brighter for class 1
    region = x[:, 17:28, 6:25, :].mean(axis=(1, 2, 3))
    assert region[y == 1].mean() > region[y == 0].mean() + 0.05


def test_image_dataset_deterministic():
    a = syn.make_image_dataset(50, 32, seed=3)[0]
    b = syn.make_image_dataset(50, 32, seed=3)[0]
    np.testing.assert_array_equal(a, b)
    c = syn.make_image_dataset(50, 32, seed=4)[0]
    assert np.abs(a - c).max() > 0


def test_paper_datasets_shifted():
    (x1, y1), (x2, y2) = syn.make_paper_datasets(image_size=32, n_train=100,
                                                 n_test=100)
    assert x2.mean() > x1.mean()            # deliberate appearance shift


def test_token_stream_structure():
    t = syn.make_token_stream(8, 128, vocab=97, seed=0, domain=0, noise=0.1)
    assert t.shape == (8, 128) and t.min() >= 0 and t.max() < 97
    nxt = (31 * t[:, :-1] + 7) % 97
    match = (t[:, 1:] == nxt).mean()
    assert match > 0.8                       # bigram rule dominates
    t2 = syn.make_token_stream(8, 128, vocab=97, seed=0, domain=1, noise=0.1)
    assert (t2[:, 1:] == (33 * t2[:, :-1] + 8) % 97).mean() > 0.8


@given(n=st.integers(40, 200), k=st.integers(2, 6), seed=st.integers(0, 50))
def test_stratified_folds_partition(n, k, seed):
    labels = np.random.default_rng(seed).integers(0, 2, n)
    folds = fd.stratified_k_folds(labels, k, seed)
    allidx = np.concatenate(folds)
    assert sorted(allidx.tolist()) == list(range(n))
    sizes = [len(f) for f in folds]
    assert max(sizes) - min(sizes) <= 2


def test_dirichlet_shards_partition_and_skew():
    labels = np.arange(400) % 2
    shards = fd.dirichlet_shards(labels, 4, alpha=0.2, seed=1)
    allidx = np.concatenate(shards)
    assert sorted(allidx.tolist()) == list(range(400))
    fracs = [labels[s].mean() for s in shards if len(s) > 10]
    assert max(fracs) - min(fracs) > 0.15    # low alpha -> visible skew
    iid = fd.iid_shards(400, 4, seed=1)
    assert sorted(np.concatenate(iid).tolist()) == list(range(400))


def test_public_round_sets_rotate():
    labels = np.arange(300) % 2
    sets_ = fd.public_round_sets(labels, rounds=5, per_round=30, seed=0)
    assert len(sets_) == 5
    for a in sets_:
        assert len(a) == 30
    flat = np.concatenate(sets_)
    assert len(np.unique(flat)) == len(flat)  # disjoint across rounds


def test_batched_iterator():
    x = np.arange(100)
    batches = list(syn.batched((x,), 32, seed=0))
    assert len(batches) == 3
    assert all(b[0].shape == (32,) for b in batches)
    seen = np.concatenate([b[0] for b in batches])
    assert len(np.unique(seen)) == 96        # no repeats
