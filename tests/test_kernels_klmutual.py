"""Fused mutual-KL kernel vs oracle + Eq.-2 mathematical properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, st

from repro.kernels import ref
from repro.kernels.kl_mutual import kl_mutual


def _logits(K, B, V, seed=0, scale=3.0):
    return jax.random.normal(jax.random.PRNGKey(seed), (K, B, V)) * scale


@pytest.mark.parametrize("K,B,V,bb,bv", [
    (2, 8, 64, 8, 32),
    (3, 16, 100, 8, 32),       # padded V (100 % 32 != 0)
    (5, 7, 257, 4, 64),        # padded B and V
    (8, 4, 512, 4, 512),       # single V block
])
def test_matches_oracle(K, B, V, bb, bv):
    logits = _logits(K, B, V)
    want = np.asarray(ref.mutual_kl(logits))
    got = np.asarray(kl_mutual(logits, block_b=bb, block_v=bv,
                               interpret=True))
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("temp", [0.5, 1.0, 2.0, 4.0])
def test_temperature(temp):
    logits = _logits(3, 8, 128, seed=1)
    want = np.asarray(ref.mutual_kl(logits, temperature=temp))
    got = np.asarray(kl_mutual(logits, temperature=temp, block_v=32,
                               interpret=True))
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 3e-5),
                                        (jnp.bfloat16, 5e-2)])
def test_dtypes(dtype, atol):
    logits = _logits(2, 8, 96).astype(dtype)
    want = np.asarray(ref.mutual_kl(logits))
    got = np.asarray(kl_mutual(logits, block_v=32, interpret=True))
    np.testing.assert_allclose(got, want, atol=atol, rtol=atol)


def test_identical_clients_zero():
    one = _logits(1, 6, 80)[0]
    logits = jnp.broadcast_to(one, (4,) + one.shape)
    got = np.asarray(kl_mutual(logits, block_v=32, interpret=True))
    np.testing.assert_allclose(got, 0.0, atol=1e-5)


@given(K=st.integers(2, 5), B=st.integers(1, 6), V=st.integers(2, 90),
       seed=st.integers(0, 1000))
def test_property_nonneg_and_oracle(K, B, V, seed):
    """KL >= 0 for every client/example; kernel == oracle (hypothesis)."""
    logits = _logits(K, B, V, seed=seed, scale=5.0)
    want = np.asarray(ref.mutual_kl(logits))
    got = np.asarray(kl_mutual(logits, block_b=4, block_v=32, interpret=True))
    assert (want >= -1e-5).all()
    np.testing.assert_allclose(got, want, atol=5e-5, rtol=5e-5)


def test_permutation_equivariance():
    """Permuting clients permutes the outputs identically (Eq. 2 symmetry)."""
    logits = _logits(4, 5, 64, seed=2)
    perm = jnp.array([2, 0, 3, 1])
    a = np.asarray(kl_mutual(logits, block_v=32, interpret=True))[perm]
    b = np.asarray(kl_mutual(logits[perm], block_v=32, interpret=True))
    np.testing.assert_allclose(a, b, atol=1e-5)


# ---------------------------------------------------------------------------
# pair-weighted kernel + custom-VJP streaming backward (the Eq.-2 TRAINING
# path: core.mutual.mutual_kl_terms routes here under kernel impls)


from repro.core.mutual import _pair_mask, mutual_kl_terms
from repro.kernels.kl_mutual import kl_mutual_pair


def _uniform_w(K):
    return (1.0 - jnp.eye(K)) / max(K - 1, 1)


@pytest.mark.parametrize("K,B,V,bb,bv", [
    (2, 8, 64, 8, 32),
    (3, 16, 100, 8, 32),       # padded V
    (5, 7, 257, 4, 64),        # padded B and V
])
def test_pair_forward_matches_oracle(K, B, V, bb, bv):
    live = _logits(K, B, V, seed=11)
    fixed = _logits(K, B, V, seed=12)
    want = np.asarray(ref.mutual_kl_pair(live, fixed, _uniform_w(K)))
    got = np.asarray(kl_mutual_pair(live, fixed, _uniform_w(K),
                                    block_b=bb, block_v=bv, interpret=True))
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)
    # single-tensor case degenerates to the eval kernel / oracle
    self_got = np.asarray(kl_mutual_pair(live, live, _uniform_w(K),
                                         block_b=bb, block_v=bv,
                                         interpret=True))
    np.testing.assert_allclose(self_got, np.asarray(ref.mutual_kl(live)),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("K,B,V,bv", [
    (2, 4, 64, 64),
    (3, 6, 100, 32),           # padded V in the streaming backward
    (4, 3, 257, 64),           # padded B and V
])
def test_vjp_matches_ad_of_oracle(K, B, V, bv):
    """grad of the custom-VJP kernel (both sides live) == jax.grad of
    ref.mutual_kl, across padded B/V shapes."""
    logits = _logits(K, B, V, seed=21)
    cot = jnp.cos(jnp.arange(K * B, dtype=jnp.float32)).reshape(K, B)
    g_ref = jax.grad(
        lambda x: jnp.sum(ref.mutual_kl(x) * cot))(logits)
    g_ker = jax.grad(lambda x: jnp.sum(
        kl_mutual_pair(x, x, _uniform_w(K), block_v=bv,
                       interpret=True) * cot))(logits)
    np.testing.assert_allclose(np.asarray(g_ker), np.asarray(g_ref),
                               atol=5e-6, rtol=1e-4)


@pytest.mark.parametrize("temp", [0.5, 2.5])
def test_vjp_temperature(temp):
    logits = _logits(3, 5, 96, seed=22)
    g_ref = jax.grad(lambda x: jnp.sum(
        ref.mutual_kl(x, temperature=temp)))(logits)
    g_ker = jax.grad(lambda x: jnp.sum(kl_mutual_pair(
        x, x, _uniform_w(3), temperature=temp, block_v=32,
        interpret=True)))(logits)
    np.testing.assert_allclose(np.asarray(g_ker), np.asarray(g_ref),
                               atol=5e-6, rtol=1e-4)


def test_vjp_fixed_side_and_part_mask():
    """Training semantics: fixed side stop-gradient'ed, participation-
    masked pair weights — kernel grads match AD of the ref graph."""
    K, B, V = 4, 6, 129
    live = _logits(K, B, V, seed=23)
    pm = jnp.asarray([1.0, 0.0, 1.0, 1.0])
    W = _pair_mask(K, pm)

    def f_ref(x):
        return jnp.sum(mutual_kl_terms(x, jax.lax.stop_gradient(x),
                                       part_mask=pm, impl="ref"))

    def f_ker(x):
        return jnp.sum(kl_mutual_pair(x, jax.lax.stop_gradient(x), W,
                                      block_v=32, interpret=True))

    np.testing.assert_allclose(np.asarray(jax.grad(f_ker)(live)),
                               np.asarray(jax.grad(f_ref)(live)),
                               atol=5e-6, rtol=1e-4)
    # absent client's row gets zero gradient through the mask structure
    g = np.asarray(jax.grad(f_ker)(live))
    np.testing.assert_allclose(g[1], 0.0, atol=1e-7)


def test_mutual_kl_terms_impl_switch_routes_to_kernel():
    """mutual_kl_terms(impl='interpret') values == ref impl; gradients
    flow through the streaming VJP and agree with the ref graph."""
    K, B, V = 3, 5, 80
    live = _logits(K, B, V, seed=24)
    a = mutual_kl_terms(live, jax.lax.stop_gradient(live), impl="ref")
    b = mutual_kl_terms(live, jax.lax.stop_gradient(live),
                        impl="interpret")
    np.testing.assert_allclose(np.asarray(b), np.asarray(a), atol=3e-5,
                               rtol=3e-5)
    ga = jax.grad(lambda x: jnp.sum(mutual_kl_terms(
        x, jax.lax.stop_gradient(x), impl="ref")))(live)
    gb = jax.grad(lambda x: jnp.sum(mutual_kl_terms(
        x, jax.lax.stop_gradient(x), impl="interpret")))(live)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(ga), atol=5e-6,
                               rtol=1e-4)


@given(K=st.integers(2, 4), B=st.integers(1, 6), V=st.integers(2, 90),
       seed=st.integers(0, 1000))
def test_property_vjp_matches_ad(K, B, V, seed):
    """Hypothesis: custom-VJP gradients track jax.grad of ref.mutual_kl
    for arbitrary (padded) shapes."""
    logits = _logits(K, B, V, seed=seed, scale=4.0)
    g_ref = jax.grad(lambda x: jnp.sum(ref.mutual_kl(x)))(logits)
    g_ker = jax.grad(lambda x: jnp.sum(kl_mutual_pair(
        x, x, _uniform_w(K), block_b=4, block_v=32,
        interpret=True)))(logits)
    np.testing.assert_allclose(np.asarray(g_ker), np.asarray(g_ref),
                               atol=1e-5, rtol=5e-4)
