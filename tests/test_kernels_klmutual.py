"""Fused mutual-KL kernel vs oracle + Eq.-2 mathematical properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.kl_mutual import kl_mutual


def _logits(K, B, V, seed=0, scale=3.0):
    return jax.random.normal(jax.random.PRNGKey(seed), (K, B, V)) * scale


@pytest.mark.parametrize("K,B,V,bb,bv", [
    (2, 8, 64, 8, 32),
    (3, 16, 100, 8, 32),       # padded V (100 % 32 != 0)
    (5, 7, 257, 4, 64),        # padded B and V
    (8, 4, 512, 4, 512),       # single V block
])
def test_matches_oracle(K, B, V, bb, bv):
    logits = _logits(K, B, V)
    want = np.asarray(ref.mutual_kl(logits))
    got = np.asarray(kl_mutual(logits, block_b=bb, block_v=bv,
                               interpret=True))
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("temp", [0.5, 1.0, 2.0, 4.0])
def test_temperature(temp):
    logits = _logits(3, 8, 128, seed=1)
    want = np.asarray(ref.mutual_kl(logits, temperature=temp))
    got = np.asarray(kl_mutual(logits, temperature=temp, block_v=32,
                               interpret=True))
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 3e-5),
                                        (jnp.bfloat16, 5e-2)])
def test_dtypes(dtype, atol):
    logits = _logits(2, 8, 96).astype(dtype)
    want = np.asarray(ref.mutual_kl(logits))
    got = np.asarray(kl_mutual(logits, block_v=32, interpret=True))
    np.testing.assert_allclose(got, want, atol=atol, rtol=atol)


def test_identical_clients_zero():
    one = _logits(1, 6, 80)[0]
    logits = jnp.broadcast_to(one, (4,) + one.shape)
    got = np.asarray(kl_mutual(logits, block_v=32, interpret=True))
    np.testing.assert_allclose(got, 0.0, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(K=st.integers(2, 5), B=st.integers(1, 6), V=st.integers(2, 90),
       seed=st.integers(0, 1000))
def test_property_nonneg_and_oracle(K, B, V, seed):
    """KL >= 0 for every client/example; kernel == oracle (hypothesis)."""
    logits = _logits(K, B, V, seed=seed, scale=5.0)
    want = np.asarray(ref.mutual_kl(logits))
    got = np.asarray(kl_mutual(logits, block_b=4, block_v=32, interpret=True))
    assert (want >= -1e-5).all()
    np.testing.assert_allclose(got, want, atol=5e-5, rtol=5e-5)


def test_permutation_equivariance():
    """Permuting clients permutes the outputs identically (Eq. 2 symmetry)."""
    logits = _logits(4, 5, 64, seed=2)
    perm = jnp.array([2, 0, 3, 1])
    a = np.asarray(kl_mutual(logits, block_v=32, interpret=True))[perm]
    b = np.asarray(kl_mutual(logits[perm], block_v=32, interpret=True))
    np.testing.assert_allclose(a, b, atol=1e-5)
