"""Logical-axis sharding rules: mapping, divisibility, tuple rules."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import sharding as shd

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 1, reason="needs a device")


class FakeMesh:
    def __init__(self, names, sizes):
        self.axis_names = tuple(names)
        self.axis_sizes = tuple(sizes)


MESH = FakeMesh(("data", "model"), (16, 16))
MESH3 = FakeMesh(("pod", "data", "model"), (2, 16, 16))


def test_basic_mapping():
    spec = shd.logical_to_spec(("batch", "seq", "heads"), MESH,
                               shape=(256, 4096, 32))
    assert spec == P("data", None, "model")


def test_tuple_rule_multi_pod():
    spec = shd.logical_to_spec(("batch", None), MESH3, shape=(256, 10))
    assert spec == P(("pod", "data"))


def test_tuple_rule_partial_divisibility():
    # batch=2 divides pod(2) but not pod*data(32): keep the prefix only
    spec = shd.logical_to_spec(("batch",), MESH3, shape=(2,))
    assert spec == P("pod")


def test_indivisible_dropped():
    # 24 heads % 16 != 0 -> replicated
    spec = shd.logical_to_spec(("batch", "heads"), MESH, shape=(32, 24))
    assert spec == P("data")


def test_duplicate_physical_axis_kept_once():
    # kv_seq and kv_heads both map to model; first occurrence wins
    spec = shd.logical_to_spec(("batch", "kv_seq", "kv_heads", None), MESH,
                               shape=(128, 32768, 16, 128))
    assert spec == P("data", "model")


def test_rules_override():
    with shd.axis_rules({"batch": ("data",), "client": "pod"}):
        spec = shd.logical_to_spec(("client", "batch"), MESH3,
                                   shape=(2, 128))
        assert spec == P("pod", "data")


def test_unknown_axis_replicated():
    spec = shd.logical_to_spec(("nonsense", "batch"), MESH, shape=(4, 32))
    assert spec == P(None, "data")


def test_constrain_noop_outside_mesh():
    x = jax.numpy.ones((8, 8))
    y = shd.constrain(x, "batch", "ff")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
