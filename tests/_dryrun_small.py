"""Subprocess body for test_dryrun_small: 8 host devices, reduced configs,
a (2, 2, 2) pod mesh — exercises the exact dry-run machinery end-to-end
without the 512-device compile cost.  Run via test_dryrun_small.py only.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import jax
import jax.numpy as jnp

from repro import sharding as shd
from repro.configs import SHAPES, get_reduced
from repro.configs.base import ShapeConfig
from repro.launch import dryrun as DR


def small_mesh():
    return shd.make_mesh((2, 2, 2), ("pod", "data", "model"))


def main():
    arch = sys.argv[1]
    method = sys.argv[2] if len(sys.argv) > 2 else "standard"
    kind = sys.argv[3] if len(sys.argv) > 3 else "train"
    cfg = get_reduced(arch)
    shape = ShapeConfig("small", seq_len=64, global_batch=8, kind=kind)
    mesh = small_mesh()
    rules = ({"batch": ("data",), "attn_batch": ("data",)}
             if method in ("dml", "mutual", "fedavg_sync") else {})
    with shd.axis_rules(rules):
        step, args, shards = DR.build_case(cfg, shape, mesh, method)
        with shd.use_mesh(mesh):
            lowered = jax.jit(step, in_shardings=shards).lower(*args)
            compiled = lowered.compile()
    stats = DR.collective_stats(compiled.as_text(), pod_stride=4)
    cost = DR.cost_dict(compiled)
    assert cost.get("flops", 0) > 0 or method == "fedavg_sync"
    print(f"OK {arch} {method} {kind} collectives={int(stats['count'])} "
          f"pod_axis={stats['pod_axis']:.0f}")


if __name__ == "__main__":
    main()
