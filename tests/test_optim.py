"""Optimizer substrate: AdamW/SGD descent, clipping, schedules, wd mask."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import optim


def _quad_setup():
    params = {"w": jnp.asarray([3.0, -2.0]), "norm": jnp.asarray([1.0])}
    def loss(p):
        return jnp.sum(p["w"] ** 2) + 0.0 * jnp.sum(p["norm"])
    return params, loss


def test_adamw_descends():
    params, loss = _quad_setup()
    state = optim.adamw_init(params)
    cfg = optim.AdamWConfig(lr=0.1, weight_decay=0.0, warmup=0,
                            total_steps=100, schedule="constant")
    l0 = float(loss(params))
    for _ in range(50):
        grads = jax.grad(loss)(params)
        params, state, m = optim.adamw_update(params, grads, state, cfg)
    assert float(loss(params)) < 0.05 * l0


def test_sgd_momentum_descends():
    params, loss = _quad_setup()
    state = optim.sgd_init(params)
    cfg = optim.SGDConfig(lr=0.05, momentum=0.9)
    for _ in range(100):
        grads = jax.grad(loss)(params)
        params, state, _ = optim.sgd_update(params, grads, state, cfg)
    assert float(loss(params)) < 0.05


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = optim.clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 20.0) < 1e-5
    assert abs(float(optim.global_norm(clipped)) - 1.0) < 1e-5
    small = {"a": jnp.full((4,), 0.01)}
    same, _ = optim.clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(same["a"]), 0.01, atol=1e-8)


def test_cosine_schedule_shape():
    lr = optim.cosine_schedule(1.0, warmup=10, total=100, final_frac=0.1)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 0.01
    assert float(lr(55)) < 1.0
    assert abs(float(lr(100)) - 0.1) < 0.02
    assert abs(float(lr(500)) - 0.1) < 0.02   # clamps after total


def test_weight_decay_skips_norms():
    """Norm/bias params must not be decayed (wd mask)."""
    params = {"w": jnp.asarray([1.0]), "final_norm": jnp.asarray([1.0])}
    zero_g = jax.tree.map(jnp.zeros_like, params)
    state = optim.adamw_init(params)
    cfg = optim.AdamWConfig(lr=0.1, weight_decay=0.5, warmup=0,
                            total_steps=10, schedule="constant",
                            clip_norm=None)
    p2, _, _ = optim.adamw_update(params, zero_g, state, cfg)
    assert float(p2["w"][0]) < 1.0            # decayed
    assert float(p2["final_norm"][0]) == 1.0  # skipped
