"""Benchmark harness — one function per paper table/figure + kernel perf.

  bench_table2   paper Table II: client accuracies, 3 frameworks (reduced)
  bench_history  paper Fig. 3/4: per-round training-loss history
  bench_comm     communication bytes/round (the bandwidth claim), CNN + LLM
  bench_kernels  kernel wrappers: us_per_call + derived FLOP counts

CSV convention: ``name,us_per_call,derived`` (plus labelled sections).
Run: PYTHONPATH=src python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.visionnet import reduced as vn_reduced
from repro.core import distributed as D
from repro.core.federated import FederatedConfig, FederatedTrainer
from repro.data.synthetic import make_paper_datasets
from repro.kernels import ref

FAST = False


def _fed_runs(rounds=6, n_train=2000, n_test=600, clients=5):
    vn = vn_reduced()
    (tr_x, tr_y), (te_x, te_y) = make_paper_datasets(
        image_size=vn.image_size, n_train=n_train, n_test=n_test)
    out = {}
    for method in ("fedavg", "async", "dml"):
        fc = FederatedConfig(method=method, n_clients=clients, rounds=rounds,
                             local_epochs=3, batch_size=16, lr=0.05,
                             delta=3, min_round=2)
        tr = FederatedTrainer(vn, fc, tr_x, tr_y)
        tr.run()
        out[method] = tr.evaluate(te_x, te_y)
    return out


_RUNS_CACHE = {}


def _runs():
    if "r" not in _RUNS_CACHE:
        if FAST:
            _RUNS_CACHE["r"] = _fed_runs(rounds=2, n_train=400, n_test=200,
                                         clients=3)
        else:
            _RUNS_CACHE["r"] = _fed_runs()
    return _RUNS_CACHE["r"]


def bench_table2() -> None:
    """Paper Table II: per-client accuracy on the unseen dataset 2."""
    print("\n# table2: framework,client,accuracy_pct (paper Table II)")
    names = {"fedavg": "vanilla_fl", "async": "async_weight_fl",
             "dml": "mutual_learning_fl_ours"}
    for method, h in _runs().items():
        for c, acc in enumerate(h.client_test_acc):
            print(f"table2,{names[method]},client{c},{100 * acc:.2f}")
        spread = 100 * (max(h.client_test_acc) - min(h.client_test_acc))
        print(f"table2,{names[method]},spread_pct,{spread:.2f}")


def bench_history() -> None:
    """Paper Fig. 3/4: round-by-round mean client loss (+ KL term for DML)."""
    print("\n# history: framework,round,mean_client_loss,mean_kl")
    for method, h in _runs().items():
        for r in h.rounds:
            print(f"history,{method},{r.round},"
                  f"{np.mean(r.client_loss):.4f},{np.mean(r.kl_loss):.5f}")


def bench_comm() -> None:
    """The bandwidth claim: measured CNN bytes + analytic LLM-scale table."""
    print("\n# comm: setting,method,bytes_per_federation")
    for method, h in _runs().items():
        print(f"comm,visionnet,{method},{h.total_comm_bytes}")
    print("# comm_llm: arch,fedavg_bytes,dml_dense_bytes,dml_top64_bytes,"
          "dense_ratio,sparse_ratio (K=5 clients, 4096-token public set)")
    from repro.core.mutual import sparse_share_bytes
    for arch in ("qwen3-4b", "dbrx-132b", "jamba-1.5-large-398b",
                 "qwen1.5-110b"):
        cfg = get_config(arch)
        c = D.comm_bytes(cfg, n_clients=5, public_tokens=4096)
        sp = sparse_share_bytes(5, 4096, 64)
        print(f"comm_llm,{arch},{c['fedavg_round']},{c['dml_round']},{sp},"
              f"{c['fedavg_round'] / max(c['dml_round'], 1):.1f}x,"
              f"{c['fedavg_round'] / sp:.0f}x")


def bench_noniid() -> None:
    """Paper §VI future work: Dirichlet non-IID client data.  Mutual
    learning's public-set consensus regularises the skewed clients."""
    print("\n# noniid: framework,alpha,client,accuracy_pct")
    vn = vn_reduced()
    n_tr, n_te, rounds = (400, 200, 2) if FAST else (2000, 600, 6)
    (tr_x, tr_y), (te_x, te_y) = make_paper_datasets(
        image_size=vn.image_size, n_train=n_tr, n_test=n_te)
    for alpha in (0.3,):
        for method in ("fedavg", "async", "dml"):
            fc = FederatedConfig(method=method, n_clients=5, rounds=rounds,
                                 local_epochs=3, batch_size=16, lr=0.05,
                                 delta=3, min_round=2, non_iid_alpha=alpha)
            t = FederatedTrainer(vn, fc, tr_x, tr_y)
            t.run()
            h = t.evaluate(te_x, te_y)
            for c, acc in enumerate(h.client_test_acc):
                print(f"noniid,{method},{alpha},client{c},{100 * acc:.2f}")


def bench_hard_task() -> None:
    """Beyond-paper observation: on a weak-signal task, weight AVERAGING
    destroys the fragile features individual clients learn, while
    prediction sharing preserves them — DML is the only framework that
    learns at signal=0.18 (see EXPERIMENTS.md §Repro)."""
    from repro.data.synthetic import make_image_dataset
    print("\n# hard_task: framework,client,accuracy_pct (signal=0.18)")
    vn = vn_reduced()
    n_tr, n_te, rounds = (400, 200, 2) if FAST else (2000, 600, 6)
    tr_x, tr_y = make_image_dataset(n_tr, vn.image_size, seed=0,
                                    brightness=0.0, noise=0.3, signal=0.18)
    te_x, te_y = make_image_dataset(n_te, vn.image_size, seed=999,
                                    brightness=0.1, noise=0.38, signal=0.18)
    for method in ("fedavg", "async", "dml"):
        fc = FederatedConfig(method=method, n_clients=5, rounds=rounds,
                             local_epochs=3, batch_size=16, lr=0.05,
                             delta=3, min_round=2)
        t = FederatedTrainer(vn, fc, tr_x, tr_y)
        t.run()
        h = t.evaluate(te_x, te_y)
        for c, acc in enumerate(h.client_test_acc):
            print(f"hard_task,{method},client{c},{100 * acc:.2f}")


def _time_call(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def bench_kernels() -> None:
    """Kernel entry points (XLA ref impl timed on CPU; derived = FLOPs).

    Wall-time of the Pallas kernels themselves is only meaningful on TPU;
    interpret mode is a correctness tool.  We time the jnp oracle (what the
    dry-run lowers) and report the analytic FLOP count per call.
    """
    print("\n# kernels: name,us_per_call,derived_flops")
    key = jax.random.PRNGKey(0)
    # mutual KL (paper Eq. 2) at LLM-ish width
    K, B, V = 4, 64, 8192
    logits = jax.random.normal(key, (K, B, V))
    f = jax.jit(lambda x: ref.mutual_kl(x))
    us = _time_call(f, logits)
    flops = K * K * B * V * 4                 # softmax + pairwise terms
    print(f"kernels,kl_mutual_ref,{us:.0f},{flops}")
    # attention
    Bq, S, H, hd = 2, 512, 8, 64
    q = jax.random.normal(key, (Bq, S, H, hd))
    f = jax.jit(lambda q: ref.attention(q, q, q))
    us = _time_call(f, q)
    print(f"kernels,attention_ref,{us:.0f},{4 * Bq * H * S * S * hd}")
    # SSD
    Bb, Sl, Hh, P, G, N = 2, 1024, 8, 64, 1, 128
    x = jax.random.normal(key, (Bb, Sl, Hh, P))
    dt = jax.nn.softplus(jax.random.normal(key, (Bb, Sl, Hh)))
    A = -jnp.exp(jax.random.normal(key, (Hh,)))
    Bm = jax.random.normal(key, (Bb, Sl, G, N))
    f = jax.jit(lambda x, dt, Bm: ref.ssd(x, dt, A, Bm, Bm, chunk=256)[0])
    us = _time_call(f, x, dt, Bm)
    chunk_flops = Bb * Hh * (Sl * 256 * (N + P) + Sl * N * P * 3)
    print(f"kernels,ssd_ref,{us:.0f},{chunk_flops}")


BENCHES = {
    "table2": bench_table2,
    "history": bench_history,
    "comm": bench_comm,
    "hard_task": bench_hard_task,
    "noniid": bench_noniid,
    "kernels": bench_kernels,
}


def main() -> None:
    global FAST
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", choices=sorted(BENCHES), default=None,
                    help="run a single bench section")
    args, _ = ap.parse_known_args()
    FAST = args.fast
    t0 = time.time()
    for name, fn in BENCHES.items():
        if args.only and name != args.only:
            continue
        t1 = time.time()
        fn()
        print(f"# section_seconds,{name},{time.time() - t1:.1f}")
    print(f"\n# total_bench_seconds,{time.time() - t0:.0f}")


if __name__ == "__main__":
    main()
