"""Benchmark harness — one function per paper table/figure + kernel perf.

  bench_table2   paper Table II: client accuracies, 3 frameworks (reduced)
  bench_history  paper Fig. 3/4: per-round training-loss history
  bench_comm     communication bytes/round (the bandwidth claim), CNN + LLM
  bench_hetero   heterogeneous-client DML (transformer+SSM+MoE) incl.
                 partial participation comm scaling
  bench_api      the unified Federation session layer: per-round jit
                 dispatch counts unchanged vs the PR-1 engine (asserted)
                 + bitwise parity + sparse-vs-dense comm ratios
  bench_sharded  device-sharded DML rounds: wall-clock + dispatches vs
                 device count (fake CPU host devices), bitwise-checked
  bench_kernels  kernel wrappers (us_per_call + FLOP/byte model + roofline
                 attribution) and the dense-vs-sparse mutual step vs k
                 (the fused top-k sparse-KL kernel's perf claim)
  bench_privacy  privacy & robustness battery: comm/accuracy/epsilon/
                 MIA-advantage per strategy, the accountant's analytic
                 epsilon curve, and honest accuracy under a colluding
                 client for plain vs trimmed/median DML
  bench_decode   serving engine: steady-state decode tokens/s + p50/p99
                 per-token latency vs batch x model-count x arch, with
                 the O(1)-dispatch, legacy-token-parity and bitwise
                 ensemble-average gates as structural rows

Output: CSV-ish lines on stdout (``name,col,col,...``) AND a
machine-readable ``BENCH_<table>.json`` per bench next to them (--out-dir,
default cwd) — the perf-trajectory input for future PRs.  Committed
baselines live in benchmarks/results/ and are gated by
``benchmarks.check_regression`` in CI.
Run: PYTHONPATH=src python -m benchmarks.run [--fast]
     PYTHONPATH=src python -m benchmarks.run --table sharded
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# the sharded table needs several XLA host devices, and the flag must be
# set BEFORE jax initialises — hence this pre-import peek at argv (both
# "--table sharded" and "--table=sharded" forms)
if any("sharded" in a for a in sys.argv) and "jax" not in sys.modules:
    os.environ["XLA_FLAGS"] = " ".join(x for x in (
        os.environ.get("XLA_FLAGS", ""),
        "--xla_force_host_platform_device_count="
        + os.environ.get("BENCH_HOST_DEVICES", "8")) if x)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.visionnet import reduced as vn_reduced
from repro.core import distributed as D
from repro.core.federated import FederatedConfig, FederatedTrainer
from repro.data.synthetic import make_paper_datasets
from repro.kernels import ref

FAST = False
OUT_DIR = "."

# section -> list of row dicts; cleared before each bench fn and dumped to
# BENCH_<bench>.json right after it, so stdout CSV and JSON never diverge
_ROWS: dict = {}


def row(section: str, **cols) -> None:
    """Record one result row: CSV-ish on stdout + collected for the JSON."""
    _ROWS.setdefault(section, []).append(cols)
    print(",".join([section] + [str(v) for v in cols.values()]))


def _dump_json(bench: str, seconds: float) -> None:
    path = os.path.join(OUT_DIR, f"BENCH_{bench}.json")
    with open(path, "w") as f:
        json.dump({"bench": bench, "seconds": round(seconds, 1),
                   "fast": FAST, "sections": _ROWS}, f, indent=2)


def _fed_runs(rounds=6, n_train=2000, n_test=600, clients=5):
    vn = vn_reduced()
    (tr_x, tr_y), (te_x, te_y) = make_paper_datasets(
        image_size=vn.image_size, n_train=n_train, n_test=n_test)
    out = {}
    for method in ("fedavg", "async", "dml"):
        fc = FederatedConfig(method=method, n_clients=clients, rounds=rounds,
                             local_epochs=3, batch_size=16, lr=0.05,
                             delta=3, min_round=2)
        tr = FederatedTrainer(vn, fc, tr_x, tr_y)
        tr.run()
        out[method] = tr.evaluate(te_x, te_y)
    return out


_RUNS_CACHE = {}


def _runs():
    if "r" not in _RUNS_CACHE:
        if FAST:
            _RUNS_CACHE["r"] = _fed_runs(rounds=2, n_train=400, n_test=200,
                                         clients=3)
        else:
            _RUNS_CACHE["r"] = _fed_runs()
    return _RUNS_CACHE["r"]


def bench_table2() -> None:
    """Paper Table II: per-client accuracy on the unseen dataset 2."""
    print("\n# table2: framework,client,accuracy_pct (paper Table II)")
    names = {"fedavg": "vanilla_fl", "async": "async_weight_fl",
             "dml": "mutual_learning_fl_ours"}
    for method, h in _runs().items():
        for c, acc in enumerate(h.client_test_acc):
            row("table2", framework=names[method], client=f"client{c}",
                accuracy_pct=round(100 * acc, 2))
        spread = 100 * (max(h.client_test_acc) - min(h.client_test_acc))
        row("table2", framework=names[method], client="spread_pct",
            accuracy_pct=round(spread, 2))


def bench_history() -> None:
    """Paper Fig. 3/4: round-by-round mean client loss (+ KL term for DML)."""
    print("\n# history: framework,round,mean_client_loss,mean_kl")
    for method, h in _runs().items():
        for r in h.rounds:
            row("history", framework=method, round=r.round,
                mean_client_loss=round(float(np.mean(r.client_loss)), 4),
                mean_kl=round(float(np.mean(r.kl_loss)), 5))


def bench_comm() -> None:
    """The bandwidth claim: measured CNN bytes + analytic LLM-scale table."""
    print("\n# comm: setting,method,bytes_per_federation")
    for method, h in _runs().items():
        row("comm", setting="visionnet", method=method,
            bytes_per_federation=h.total_comm_bytes)
    print("# comm_llm: arch,fedavg_bytes,dml_dense_bytes,dml_top64_bytes,"
          "dense_ratio,sparse_ratio (K=5 clients, 4096-token public set)")
    from repro.core.mutual import sparse_share_bytes
    for arch in ("qwen3-4b", "dbrx-132b", "jamba-1.5-large-398b",
                 "qwen1.5-110b"):
        cfg = get_config(arch)
        c = D.comm_bytes(cfg, n_clients=5, public_tokens=4096)
        sp = sparse_share_bytes(5, 4096, 64)
        row("comm_llm", arch=arch, fedavg_bytes=c["fedavg_round"],
            dml_dense_bytes=c["dml_round"], dml_top64_bytes=sp,
            dense_ratio=f"{c['fedavg_round'] / max(c['dml_round'], 1):.1f}x",
            sparse_ratio=f"{c['fedavg_round'] / sp:.0f}x")


def bench_noniid() -> None:
    """Paper §VI future work: Dirichlet non-IID client data.  Mutual
    learning's public-set consensus regularises the skewed clients."""
    print("\n# noniid: framework,alpha,client,accuracy_pct")
    vn = vn_reduced()
    n_tr, n_te, rounds = (400, 200, 2) if FAST else (2000, 600, 6)
    (tr_x, tr_y), (te_x, te_y) = make_paper_datasets(
        image_size=vn.image_size, n_train=n_tr, n_test=n_te)
    for alpha in (0.3,):
        for method in ("fedavg", "async", "dml"):
            fc = FederatedConfig(method=method, n_clients=5, rounds=rounds,
                                 local_epochs=3, batch_size=16, lr=0.05,
                                 delta=3, min_round=2, non_iid_alpha=alpha)
            t = FederatedTrainer(vn, fc, tr_x, tr_y)
            t.run()
            h = t.evaluate(te_x, te_y)
            for c, acc in enumerate(h.client_test_acc):
                row("noniid", framework=method, alpha=alpha,
                    client=f"client{c}", accuracy_pct=round(100 * acc, 2))


def bench_hard_task() -> None:
    """Beyond-paper observation: on a weak-signal task, weight AVERAGING
    destroys the fragile features individual clients learn, while
    prediction sharing preserves them — DML is the only framework that
    learns at signal=0.18 (see EXPERIMENTS.md §Repro)."""
    from repro.data.synthetic import make_image_dataset
    print("\n# hard_task: framework,client,accuracy_pct (signal=0.18)")
    vn = vn_reduced()
    n_tr, n_te, rounds = (400, 200, 2) if FAST else (2000, 600, 6)
    tr_x, tr_y = make_image_dataset(n_tr, vn.image_size, seed=0,
                                    brightness=0.0, noise=0.3, signal=0.18)
    te_x, te_y = make_image_dataset(n_te, vn.image_size, seed=999,
                                    brightness=0.1, noise=0.38, signal=0.18)
    for method in ("fedavg", "async", "dml"):
        fc = FederatedConfig(method=method, n_clients=5, rounds=rounds,
                             local_epochs=3, batch_size=16, lr=0.05,
                             delta=3, min_round=2)
        t = FederatedTrainer(vn, fc, tr_x, tr_y)
        t.run()
        h = t.evaluate(te_x, te_y)
        for c, acc in enumerate(h.client_test_acc):
            row("hard_task", framework=method, client=f"client{c}",
                accuracy_pct=round(100 * acc, 2))


def bench_hetero() -> None:
    """Heterogeneous-client DML (the §I motivation): a dense transformer,
    an attention-free SSM, and a fine-grained MoE federate by prediction
    sharing — weight averaging is undefined across their pytrees.  Also
    reports partial-participation (M < K) communication scaling."""
    from repro.core.hetero import HeteroConfig, HeteroTrainer, make_lm_pool
    archs = ("qwen3-4b", "mamba2-780m", "dbrx-132b")
    rounds = 2 if FAST else 4
    print("\n# hetero: participation,round,mean_local_loss,mean_kl,comm_bytes")
    base = HeteroConfig(archs=archs, rounds=rounds, local_epochs=1,
                        batch_size=4, public_batch=4, seed=0)
    pool, labels = make_lm_pool(
        ((1 + len(archs)) * rounds + 1) * 8, 32,
        512, seed=0)
    evals = {}
    for m in (0, 2):                       # full vs 2-of-3 participation
        hc = HeteroConfig(**{**base.__dict__, "participation": m})
        tr = HeteroTrainer(hc, pool, labels)
        h = tr.run()
        for rl in h.rounds:
            live = [rl.client_loss[c] for c in rl.participants]
            row("hetero", participation=m or len(archs), round=rl.round,
                mean_local_loss=round(float(np.mean(live)), 4),
                mean_kl=round(float(np.mean(
                    [rl.kl_loss[c] for c in rl.participants])), 5),
                comm_bytes=rl.comm_bytes)
        evals[m] = (tr.evaluate(), tr)
    print("# hetero_eval: participation,client,arch,family,eval_loss,"
          "total_comm_bytes")
    for m, (h, tr) in evals.items():
        for c, loss in enumerate(h.client_eval_loss):
            row("hetero_eval", participation=m or len(archs),
                client=f"client{c}", arch=archs[c],
                family=tr._models[archs[c]].family,
                eval_loss=round(loss, 4),
                total_comm_bytes=h.total_comm_bytes)


def bench_api() -> None:
    """The unified Federation API has NO abstraction overhead: for every
    strategy the session layer dispatches exactly the per-round jitted
    programs of the PR-1 engine (dml: local_scan + mutual_scan; fedavg:
    local_scan; async: 2x local_scan + accuracy_scan) and reproduces the
    legacy FederatedConfig-driven trainer bitwise.  Also reports the
    sparse-vs-dense comm ratio of the hetero population."""
    from repro.api import (DML, AsyncWeights, FedAvg, Federation,
                           HeteroClients, SparseDML, VisionClients,
                           make_lm_pool)
    # per-round dispatch counts of the PR-1 engine (asserted, not assumed)
    PR1_DISPATCHES = {"dml": {"local_scan": 1, "mutual_scan": 1},
                      "fedavg": {"local_scan": 1},
                      "async": {"local_scan": 2, "accuracy_scan": 1}}
    print("\n# api: strategy,dispatches_per_round,programs,"
          "bitwise_vs_legacy,comm_bytes_per_round")
    vn = vn_reduced()
    rounds = 2
    n_tr = 400 if FAST else 1200
    (tr_x, tr_y), _ = make_paper_datasets(image_size=vn.image_size,
                                          n_train=n_tr, n_test=40)
    strategies = {"dml": lambda: DML(), "fedavg": FedAvg,
                  "async": lambda: AsyncWeights(delta=2, min_round=0)}
    for name, make in strategies.items():
        fc = FederatedConfig(method=name, n_clients=3, rounds=rounds,
                             local_epochs=2, batch_size=16, delta=2,
                             min_round=0, seed=0)
        legacy = FederatedTrainer(vn, fc, tr_x, tr_y)
        legacy.run()
        fed = Federation(VisionClients(vn, tr_x, tr_y, n_clients=3,
                                       rounds=rounds, local_epochs=2,
                                       batch_size=16, seed=0), make())
        fed.run()
        bitwise = all(
            np.array_equal(np.asarray(x), np.asarray(y))
            for x, y in zip(jax.tree.leaves(legacy.client_params),
                            jax.tree.leaves(fed.population.client_params)))
        assert bitwise, f"{name}: Federation diverged from legacy trainer"
        progs = [p for r, p in fed.dispatch_log if r == rounds - 1]
        counts = {p: progs.count(p) for p in sorted(set(progs))}
        assert counts == PR1_DISPATCHES[name], (
            f"{name}: dispatch counts {counts} != PR-1 engine "
            f"{PR1_DISPATCHES[name]} — the session layer added overhead")
        row("api", strategy=name, dispatches_per_round=len(progs),
            programs="+".join(f"{k}x{v}" for k, v in counts.items()),
            bitwise_vs_legacy=bitwise,
            comm_bytes_per_round=fed.history.rounds[-1].comm_bytes)
    # sparse top-k vs dense comm on the hetero population
    print("# api_sparse: strategy,k,comm_bytes_per_federation,vs_dense")
    pool, labels = make_lm_pool(160, 24, 512, seed=0)
    mk_pop = lambda: HeteroClients(("qwen3-4b", "mamba2-780m"), pool,
                                   labels, rounds=2, local_epochs=1,
                                   batch_size=2, public_batch=2, seed=0)
    dense = Federation(mk_pop(), DML())
    hd = dense.run()
    row("api_sparse", strategy="dml", k="-",
        comm_bytes_per_federation=hd.total_comm_bytes, vs_dense="1.0x")
    for k in (8, 64):
        sp = Federation(mk_pop(), SparseDML(k=k))
        hs = sp.run()
        assert hs.total_comm_bytes < hd.total_comm_bytes
        row("api_sparse", strategy="sparse-dml", k=k,
            comm_bytes_per_federation=hs.total_comm_bytes,
            vs_dense=f"{hd.total_comm_bytes / hs.total_comm_bytes:.1f}x")


def bench_sharded() -> None:
    """Device-sharded federated rounds (core.federated + shard_map over a
    ``clients`` mesh): steady-state round wall-clock and jitted dispatches
    per round vs device count, on fake CPU host devices.  device_count=1
    is the unsharded engine baseline; every sharded run's final state is
    checked bitwise against it (the engine's parity guarantee)."""
    from repro.core.federated import FederatedConfig, FederatedTrainer
    from repro.launch.mesh import make_client_mesh
    from repro.configs.visionnet import reduced as vn_reduced
    print("\n# sharded: device_count,clients,compile_round_s,"
          "steady_round_s,dispatches_per_round,comm_bytes_per_round,"
          "bitwise_vs_unsharded")
    n_avail = len(jax.devices())
    if n_avail < 2:
        print("# sharded: skipped — 1 visible device (run via "
              "`--table sharded`, which sets "
              "--xla_force_host_platform_device_count before jax init)")
        return
    K = 8
    rounds = 2 if FAST else 4
    n_tr = 600 if FAST else 1600
    vn = vn_reduced()
    (tr_x, tr_y), _ = make_paper_datasets(image_size=vn.image_size,
                                          n_train=n_tr, n_test=40)
    baseline = None
    for n_dev in (1, 2, 4, 8):
        if n_dev > n_avail:
            print(f"# sharded: skipping device_count={n_dev} "
                  f"(only {n_avail} devices; run with XLA_FLAGS="
                  "--xla_force_host_platform_device_count=8)")
            continue
        mesh = None if n_dev == 1 else make_client_mesh(n_dev)
        fc = FederatedConfig(method="dml", n_clients=K, rounds=rounds,
                             local_epochs=1, batch_size=16, seed=0)
        tr = FederatedTrainer(vn, fc, tr_x, tr_y, mesh=mesh)
        t0 = time.perf_counter()
        tr.run(until=1)                     # compile + round 0
        t_compile = time.perf_counter() - t0
        t0 = time.perf_counter()
        tr.run()                            # steady-state rounds
        steady = (time.perf_counter() - t0) / max(rounds - 1, 1)
        disp = len([1 for r, _ in tr.dispatch_log if r == rounds - 1])
        comm = tr.history.rounds[-1].comm_bytes
        if mesh is None:
            baseline = tr
            bitwise = "ref"
        else:
            bitwise = all(
                np.array_equal(np.asarray(x), np.asarray(y))
                for x, y in zip(jax.tree.leaves(baseline.client_params),
                                jax.tree.leaves(tr.client_params)))
            assert bitwise, f"sharded n_dev={n_dev} diverged from unsharded"
        row("sharded", device_count=n_dev, clients=K,
            compile_round_s=round(t_compile, 2),
            steady_round_s=round(steady, 3), dispatches_per_round=disp,
            comm_bytes_per_round=comm, bitwise_vs_unsharded=bitwise)


def _time_call(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def bench_kernels() -> None:
    """Kernel entry points + the dense-vs-sparse mutual step (the PR's
    perf claim).

    Wall-time of the compiled Pallas kernels is only meaningful on TPU;
    interpret mode is a correctness tool whose wall-clock tracks the
    kernel's BLOCK structure (work per vocab block), so the sparse table
    times both the XLA ref graph and the interpreted kernel.  Every row
    carries the analytic FLOP/byte model + the shared roofline attribution
    (``analysis.roofline.roofline_terms`` at V5E peaks): ``roofline_frac``
    = t_compute / max-term, ``bottleneck`` = the binding term.
    """
    from repro.analysis.roofline import roofline_terms
    from repro.core import mutual

    def _rl(flops, hbm, coll=0.0):
        t = roofline_terms(flops, hbm, coll)
        return {"roofline_frac": round(t["roofline_frac"], 3),
                "bottleneck": t["dominant"].replace("t_", "")}

    print("\n# kernels: name,us_per_call,derived_flops,derived_hbm_bytes,"
          "roofline_frac,bottleneck")
    key = jax.random.PRNGKey(0)
    # mutual KL (paper Eq. 2) at LLM-ish width
    K, B, V = 4, 64, 8192
    logits = jax.random.normal(key, (K, B, V))
    f = jax.jit(lambda x: ref.mutual_kl(x))
    us = _time_call(f, logits)
    flops = K * K * B * V * 4                 # softmax + pairwise terms
    hbm = 4 * (K * B * V + K * K * B * V)     # live + every received tensor
    row("kernels", name="kl_mutual_ref", us_per_call=round(us),
        derived_flops=flops, derived_hbm_bytes=hbm, **_rl(flops, hbm))
    # attention
    Bq, S, H, hd = 2, 512, 8, 64
    q = jax.random.normal(key, (Bq, S, H, hd))
    f = jax.jit(lambda q: ref.attention(q, q, q))
    us = _time_call(f, q)
    flops = 4 * Bq * H * S * S * hd
    hbm = 4 * 4 * Bq * S * H * hd             # q,k,v,out (flash-style IO)
    row("kernels", name="attention_ref", us_per_call=round(us),
        derived_flops=flops, derived_hbm_bytes=hbm, **_rl(flops, hbm))
    # SSD
    Bb, Sl, Hh, P, G, N = 2, 1024, 8, 64, 1, 128
    x = jax.random.normal(key, (Bb, Sl, Hh, P))
    dt = jax.nn.softplus(jax.random.normal(key, (Bb, Sl, Hh)))
    A = -jnp.exp(jax.random.normal(key, (Hh,)))
    Bm = jax.random.normal(key, (Bb, Sl, G, N))
    f = jax.jit(lambda x, dt, Bm: ref.ssd(x, dt, A, Bm, Bm, chunk=256)[0])
    us = _time_call(f, x, dt, Bm)
    flops = Bb * Hh * (Sl * 256 * (N + P) + Sl * N * P * 3)
    hbm = 4 * (2 * Bb * Sl * Hh * P + 2 * Bb * Sl * G * N + Bb * Sl * Hh)
    row("kernels", name="ssd_ref", us_per_call=round(us),
        derived_flops=flops, derived_hbm_bytes=hbm, **_rl(flops, hbm))

    # -- dense vs sparse mutual step (value+grad) vs k --------------------
    # The tentpole claim: SparseDML's combine FLOPs/HBM traffic scale with
    # the shared top-k size, not the vocab.  step="dense" is the Eq.-2 step
    # SparseDML replaces (k column = V); step="sparse" rows are the top-k
    # step at k << V.  share_bytes is what goes on the wire per round.
    # NOTE on wall-clock: on CPU the XLA *ref* sparse backward scatter-adds
    # into (K,B,V) per peer — O(K^2 B V) traffic, same order as dense — so
    # only the k-series trend is meaningful there; the streaming custom-VJP
    # kernel path (timed via interpret; compiled on TPU) is the one whose
    # traffic actually scales with k (see the derived columns).
    print("# kernels_sparse: step,impl,k,us_per_call,share_bytes,"
          "derived_flops,derived_hbm_bytes,roofline_frac,bottleneck,"
          "vs_dense")
    K, B, V = 4, 128, 4096
    ks = (128, 32, 8)
    live = jax.random.normal(jax.random.PRNGKey(1), (K, B, V), jnp.float32)
    logp = jax.nn.log_softmax(live, axis=-1)
    reps = 3 if FAST else 10
    for impl in ("ref", "interpret"):
        if impl == "interpret" and FAST:
            continue                      # interpreter is slow; full runs only
        dense = jax.jit(jax.grad(
            lambda l: jnp.sum(mutual.mutual_kl_loss(l, impl=impl))))
        dense_us = _time_call(dense, live, reps=reps)
        flops = 3 * 4 * K * K * B * V          # fwd + bwd ~ 3x fwd
        hbm = 3 * 4 * (K * B * V + K * K * B * V)
        share = K * B * V * 4
        row("kernels_sparse", step="dense", impl=impl, k=V,
            us_per_call=round(dense_us), share_bytes=share,
            derived_flops=flops, derived_hbm_bytes=hbm,
            **_rl(flops, hbm, share), vs_dense="1.0x")
        for k in ks:
            vals, idx = jax.lax.top_k(logp, k)
            step = jax.jit(lambda l, i, v, _impl=impl: jax.grad(
                lambda ll: jnp.sum(mutual.sparse_mutual_kl_loss(
                    ll, i, v, impl=_impl)))(l))
            us = _time_call(step, live, idx, vals, reps=reps)
            # live softmax/entropy is O(V); every received-side term is O(k)
            flops = 3 * (4 * K * B * V + 6 * K * (K - 1) * B * k)
            hbm = 3 * 4 * (K * B * V + 2 * K * (K - 1) * B * k)
            share = 2 * K * B * k * 8
            row("kernels_sparse", step="sparse", impl=impl, k=k,
                us_per_call=round(us), share_bytes=share,
                derived_flops=flops, derived_hbm_bytes=hbm,
                **_rl(flops, hbm, share),
                vs_dense=f"{dense_us / max(us, 1e-9):.1f}x")

    # -- train step vs forward step, per impl -----------------------------
    # Since the flash-attention / SSD kernels carry custom VJPs, a training
    # step runs the SAME impl it runs forward (no grad-time xla_flash
    # downgrade), so the fwd+bwd rows below differentiate straight through
    # the kernels.  derived_flops is the 2ND-forward / 6ND-train parameter
    # model (deterministic, regression-gated); us_per_call is reported.
    print("# kernels_train: impl,step,us_per_call,derived_flops")
    from repro.configs import get_reduced
    from repro.models import transformer as tfm

    cfg = get_reduced("qwen3-4b")
    Bt, St = 2, 64
    tokens = jax.random.randint(jax.random.PRNGKey(2), (Bt, St), 0,
                                cfg.vocab_size)
    params = tfm.init_model(jax.random.PRNGKey(3), cfg)
    n_active = cfg.active_param_count()
    reps = 2 if FAST else 5
    for impl in ("ref", "interpret"):
        fwd = jax.jit(lambda p, t, _i=impl: tfm.loss_fn(p, cfg, t,
                                                        impl=_i)[0])
        train = jax.jit(jax.grad(lambda p, t, _i=impl: tfm.loss_fn(
            p, cfg, t, impl=_i)[0]))
        us_f = _time_call(fwd, params, tokens, reps=reps)
        us_t = _time_call(train, params, tokens, reps=reps)
        row("kernels_train", impl=impl, step="fwd",
            us_per_call=round(us_f), derived_flops=2 * n_active * Bt * St)
        row("kernels_train", impl=impl, step="fwd+bwd",
            us_per_call=round(us_t), derived_flops=6 * n_active * Bt * St)


def bench_privacy() -> None:
    """Privacy & robustness battery (ISSUE 7): what each sharing strategy
    costs on the wire, what it gives up to a membership-inference
    adversary, what (eps, delta) the DP variant certifies, and how the
    robust combiners hold up under a colluding client.

      privacy         strategy,comm_bytes,accuracy_pct,epsilon,
                      mia_advantage — comm is gated deterministically;
                      accuracy/advantage/epsilon are reported (volatile)
                      but their ORDERING is a structural invariant
                      (fedavg leaks most, dp-dml never more than dml)
      privacy_dp      the analytic accountant curve: epsilon vs sigma and
                      vs composed releases (deterministic math, gated;
                      epsilon strictly decreasing in sigma is structural)
      privacy_robust  honest-client accuracy, attack x strategy: plain
                      DML collapses under one colluder in four, the
                      trimmed/median combiners hold (structural)
    """
    from repro.api import Federation, VisionClients, get_strategy
    from repro.core import stacking
    from repro.privacy import gaussian_epsilon
    from repro.privacy.attacks import (collect_client_payloads, payload_mia,
                                       weight_upload_mia)
    vn = vn_reduced().replace(image_size=16)
    seed = 0

    # -- strategy table: comm / accuracy / epsilon / MIA advantage --------
    print("\n# privacy: strategy,comm_bytes,accuracy_pct,epsilon,"
          "mia_advantage")
    K, R, BS = 4, 3, 8
    LE, N, mia_steps = (12, 160, 200) if FAST else (20, 220, 300)
    rng = np.random.default_rng(seed)
    imgs = rng.normal(size=(N, 16, 16, 3)).astype(np.float32)
    labs = (imgs.mean(axis=(1, 2, 3)) > 0).astype(np.float32)
    rand_mask = rng.random(N) < 0.4
    labs[rand_mask] = (rng.random(int(rand_mask.sum())) > 0.5
                       ).astype(np.float32)
    test = rng.normal(size=(200, 16, 16, 3)).astype(np.float32)
    tlab = (test.mean(axis=(1, 2, 3)) > 0).astype(np.float32)

    def make_pop(rounds=R):
        return VisionClients(vn, imgs, labs, n_clients=K, rounds=rounds,
                             local_epochs=LE, batch_size=BS, lr=0.05,
                             seed=seed, record_payloads=True)

    def mem_non(pop, client):
        other = (client + 1) % K
        mem = np.unique(np.concatenate([f[client] for f in pop.fold_log]))
        non = np.setdiff1d(
            np.unique(np.concatenate([f[other] for f in pop.fold_log])), mem)
        return mem, non

    def payload_probe(pop):
        advs = []
        for c in range(K):
            mem, non = mem_non(pop, c)
            pi, pp = collect_client_payloads(pop.payload_log, imgs, c)
            advs.append(payload_mia(vn, pi, pp, imgs, labs, mem, non,
                                    jax.random.PRNGKey(1000 + c),
                                    steps=mia_steps))
        return float(np.mean(advs))

    # FedAvg upload tap: run the schedule, then one extra local phase IS
    # the weight upload the eavesdropper scores
    pop_fa = make_pop(rounds=R + 1)
    fed_fa = Federation(pop_fa, get_strategy("fedavg"))
    fed_fa.run(until=R)
    pop_fa.begin_round(R)
    part = list(range(K))
    pop_fa.local_phase(R, part, pop_fa.part_mask(part))
    advs = []
    for c in range(K):
        mem, non = mem_non(pop_fa, c)
        cp = stacking.client_slice(pop_fa.client_params, c)
        advs.append(weight_upload_mia(cp, vn, imgs, labs, mem, non))
    acc_fa = float(np.mean(
        fed_fa.evaluate(split=(test, tlab)).client_test_acc))
    row("privacy", strategy="fedavg",
        comm_bytes=fed_fa.history.total_comm_bytes,
        accuracy_pct=round(100 * acc_fa, 2), epsilon="inf",
        mia_advantage=round(float(np.mean(advs)), 3))

    specs = [("dml", {}), ("dp-dml", {"dp_noise_multiplier": 1.0}),
             ("trimmed-dml", {"trim": 1}), ("median-dml", {})]
    for name, knobs in specs:
        pop = make_pop()
        fed = Federation(pop, get_strategy(name, **knobs))
        fed.run()
        acc = float(np.mean(
            fed.evaluate(split=(test, tlab)).client_test_acc))
        eps = (round(fed.strategy.epsilon(), 3)
               if hasattr(fed.strategy, "epsilon") else "inf")
        row("privacy", strategy=name,
            comm_bytes=fed.history.total_comm_bytes,
            accuracy_pct=round(100 * acc, 2), epsilon=eps,
            mia_advantage=round(payload_probe(pop), 3))

    # -- the accountant's analytic curve ----------------------------------
    print("# privacy_dp: sigma,releases,delta,epsilon")
    for sigma in (0.5, 1.0, 2.0, 4.0):
        row("privacy_dp", sigma=sigma, releases=1, delta=1e-5,
            epsilon=round(gaussian_epsilon(sigma, 1e-5), 6))
    from repro.privacy import RDPAccountant
    for releases in (3, 12, 48):
        acc = RDPAccountant()
        acc.step(1.0, releases=releases)
        row("privacy_dp", sigma=1.0, releases=releases, delta=1e-5,
            epsilon=round(acc.epsilon(1e-5), 6))

    # -- Byzantine collusion vs the robust combiners ----------------------
    print("# privacy_robust: strategy,attack,honest_accuracy_pct")
    Rb, kl, me, le, off, lr = (3, 5.0, 3, 2, 0.3, 0.03) if FAST \
        else (4, 5.0, 3, 2, 0.3, 0.03)
    rngb = np.random.default_rng(seed)

    def make_xy(n):
        y = (rngb.random(n) > 0.5).astype(np.float32)
        x = rngb.normal(size=(n, 16, 16, 3)).astype(np.float32)
        x += (y * 2 - 1)[:, None, None, None] * off
        return x, y

    bimgs, blabs = make_xy(420)
    btest, btlab = make_xy(300)
    byz = {K - 1: "collude"}
    for name, attacked, knobs in [
            ("dml", False, {}), ("dml", True, {}),
            ("trimmed-dml", True, {"trim": 1}), ("median-dml", True, {})]:
        pop = VisionClients(vn, bimgs, blabs, n_clients=K, rounds=Rb,
                            local_epochs=le, batch_size=16, seed=seed,
                            lr=lr, byzantine=byz if attacked else None)
        fed = Federation(pop, get_strategy(name, kl_weight=kl,
                                           mutual_epochs=me, **knobs))
        fed.run()
        h = fed.evaluate(split=(btest, btlab))
        honest = float(np.mean([a for c, a in enumerate(h.client_test_acc)
                                if c != K - 1]))
        row("privacy_robust", strategy=name,
            attack="collude" if attacked else "none",
            honest_accuracy_pct=round(100 * honest, 2))


def bench_decode() -> None:
    """Serving decode (the serving-subsystem tentpole): steady-state
    tokens/s + per-token latency vs batch x model-count x arch, and the
    engine's structural guarantees as gated rows —

      decode          throughput/latency grid.  ``decode_dispatches`` is
                      the per-generate device-program count (gated
                      deterministically); compile/steady/p50/p99 are
                      wall-clock info.  p50/p99 time the SINGLE-step
                      decode program (the chunk=1 continuous-serving
                      dispatch); steady_tok_s times the fused full-length
                      scan.
      decode_dispatch dispatches per generate at two gen_lens — the O(1)
                      claim: equal counts regardless of gen_len
                      (structural).
      decode_parity   ok-flag rows (MUST_BE_TRUE): fused-scan tokens ==
                      legacy per-token Python loop; ensemble-average
                      logits bitwise == the standalone vmapped oracle.
    """
    from repro.configs import get_reduced
    from repro.launch.serve import greedy_generate
    from repro.models import transformer as tfm
    from repro.serve import ServeEngine

    GEN, MAX_SEQ, S0 = 16, 64, 8
    reps = 3 if FAST else 10
    lat_reps = 8 if FAST else 30
    grid = [("qwen3-4b", 1), ("mamba2-780m", 1), ("qwen3-4b", 3)]
    rng = np.random.default_rng(0)

    def make(arch, models):
        cfg = get_reduced(arch)
        if models == 1:
            return cfg, tfm.init_model(jax.random.PRNGKey(0), cfg), "single"
        params = jax.vmap(lambda k: tfm.init_model(k, cfg))(
            jax.random.split(jax.random.PRNGKey(0), models))
        return cfg, params, "average"

    print("\n# decode: arch,models,batch,gen_len,decode_dispatches,"
          "compile_s,steady_tok_s,p50_ms,p99_ms")
    for arch, models in grid:
        cfg, params, mode = make(arch, models)
        for batch in (1, 2, 4):
            prompts = rng.integers(0, cfg.vocab_size,
                                   (batch, S0)).astype(np.int32)
            eng = ServeEngine(cfg, params, mode=mode, slots=batch,
                              max_seq=MAX_SEQ)
            t0 = time.perf_counter()
            eng.generate(prompts, GEN)
            compile_s = time.perf_counter() - t0
            n0 = len(eng.dispatch_log)
            t0 = time.perf_counter()
            for _ in range(reps):
                eng.generate(prompts, GEN)
            steady = (time.perf_counter() - t0) / reps
            disp = (len(eng.dispatch_log) - n0) // reps
            # per-token latency distribution: the chunk=1 decode program
            lg, cache = eng._prefill_prog()(eng.params,
                                            jnp.asarray(prompts), None)
            cidx = jnp.zeros((batch,), jnp.int32)
            key = jax.random.PRNGKey(0)
            tok0, _ = eng._first_token_prog()(lg, cidx, key)
            sd = eng._decode_prog(1)
            out = sd(eng.params, tok0[:, None], cache, jnp.int32(S0), key,
                     cidx)
            jax.block_until_ready(out[0])              # compile
            lats = []
            tok, cache, pos, key = out[3], out[2], out[4], out[5]
            for _ in range(lat_reps):
                t1 = time.perf_counter()
                out = sd(eng.params, tok, cache, pos, key, cidx)
                jax.block_until_ready(out[0])
                lats.append((time.perf_counter() - t1) * 1e3)
                tok, cache, pos, key = out[3], out[2], out[4], out[5]
            row("decode", arch=arch, models=models, batch=batch,
                gen_len=GEN, decode_dispatches=disp,
                compile_s=round(compile_s, 2),
                steady_tok_s=round(batch * GEN / steady, 1),
                p50_ms=round(float(np.percentile(lats, 50)), 3),
                p99_ms=round(float(np.percentile(lats, 99)), 3))

    print("# decode_dispatch: arch,models,gen_len,dispatches")
    for arch, models in grid:
        cfg, params, mode = make(arch, models)
        prompts = rng.integers(0, cfg.vocab_size, (2, S0)).astype(np.int32)
        for gl in (4, 16):
            eng = ServeEngine(cfg, params, mode=mode, slots=2,
                              max_seq=MAX_SEQ)
            eng.generate(prompts, gl)
            row("decode_dispatch", arch=arch, models=models, gen_len=gl,
                dispatches=len(eng.dispatch_log))

    print("# decode_parity: arch,models,check,ok")
    for arch, models in grid[:2]:
        cfg, params, mode = make(arch, models)
        prompts = rng.integers(0, cfg.vocab_size, (2, S0)).astype(np.int32)
        eng = ServeEngine(cfg, params, mode=mode, slots=2, max_seq=MAX_SEQ)
        legacy = np.asarray(greedy_generate(cfg, params,
                                            jnp.asarray(prompts), GEN))
        ok = bool(np.array_equal(eng.generate(prompts, GEN), legacy))
        row("decode_parity", arch=arch, models=models,
            check="tokens_match_legacy", ok=ok)
    # ensemble-average bitwise vs the independently-jitted vmapped oracle
    arch, models = grid[2]
    cfg, params, _ = make(arch, models)
    prompts = rng.integers(0, cfg.vocab_size, (2, S0)).astype(np.int32)
    eng = ServeEngine(cfg, params, mode="average", slots=2, max_seq=MAX_SEQ)
    G = 5
    toks, lg = eng.generate(prompts, G, return_logits=True)
    pre = jax.jit(lambda ps, t: jax.vmap(
        lambda p: tfm.prefill(p, cfg, t, None, max_seq=MAX_SEQ))(ps))
    step = jax.jit(lambda ps, tok, c, pos: (
        lambda lc: (jnp.mean(lc[0], axis=0), lc[1]))(
            jax.vmap(lambda p, cc: tfm.decode_step(p, cfg, tok, cc, pos))(
                ps, c)))
    l0, cache = pre(params, jnp.asarray(prompts))
    tok = jnp.argmax(jnp.mean(l0, 0), -1)[:, None].astype(jnp.int32)
    ok = True
    for t in range(G):
        ok &= bool(np.array_equal(np.asarray(tok[:, 0]), toks[:, t]))
        lo, cache = step(params, tok, cache, jnp.int32(S0 + t))
        ok &= bool(np.array_equal(np.asarray(lo), lg[:, t]))
        tok = jnp.argmax(lo, -1)[:, None].astype(jnp.int32)
    row("decode_parity", arch=arch, models=models,
        check="bitwise_ensemble_avg_vs_oracle", ok=ok)


BENCHES = {
    "table2": bench_table2,
    "history": bench_history,
    "comm": bench_comm,
    "hard_task": bench_hard_task,
    "noniid": bench_noniid,
    "hetero": bench_hetero,
    "api": bench_api,
    "sharded": bench_sharded,
    "kernels": bench_kernels,
    "privacy": bench_privacy,
    "decode": bench_decode,
}


def main() -> None:
    global FAST, OUT_DIR
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", choices=sorted(BENCHES), default=None,
                    help="run a single bench section")
    ap.add_argument("--table", dest="only", choices=sorted(BENCHES),
                    help="alias for --only")
    ap.add_argument("--out-dir", default=".",
                    help="directory for BENCH_<table>.json files")
    args, _ = ap.parse_known_args()
    FAST = args.fast
    OUT_DIR = args.out_dir
    os.makedirs(OUT_DIR, exist_ok=True)
    t0 = time.time()
    for name, fn in BENCHES.items():
        if args.only and name != args.only:
            continue
        t1 = time.time()
        _ROWS.clear()
        fn()
        dt = time.time() - t1
        _dump_json(name, dt)
        print(f"# section_seconds,{name},{dt:.1f}")
    print(f"\n# total_bench_seconds,{time.time() - t0:.0f}")


if __name__ == "__main__":
    main()
