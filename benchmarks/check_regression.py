"""Benchmark regression gate — compare BENCH_<table>.json runs.

Two jobs, one tool:

  1. STRUCTURAL invariants of a single results dir (always checked):
     bitwise-parity flags true, sparse share_bytes < dense, the sparse
     mutual-step series monotone in k (wall-clock with a noise factor,
     the derived FLOP/HBM/wire models strictly), the privacy
     battery's orderings (fedavg leaks most, epsilon monotone in
     sigma/releases, robust combiners beat poisoned plain DML), and the
     serving engine's guarantees (dispatches per generate constant in
     gen_len; ensemble-average bitwise vs the vmapped oracle; fused
     decode token-parity with the legacy loop; steady-state tokens/s
     improves with batch for at least one arch).
  2. REGRESSION vs a committed baseline (when --current is given):
     deterministic tracked metrics (comm bytes, dispatch counts, derived
     FLOP/byte models) may not grow more than --tol (default 20%).
     Wall-clock columns are machine-dependent and reported as info only.

Usage:
  python -m benchmarks.check_regression --baseline benchmarks/results
  python -m benchmarks.check_regression --baseline benchmarks/results \
      --current /tmp/bench_out [--tol 0.2]

Exit 1 on any violated gate; CI runs this after regenerating the tables.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List, Tuple

# section -> columns gated deterministically (lower-or-equal is healthy;
# >tol growth vs baseline fails).  Everything numeric NOT listed here or in
# WALLCLOCK is treated as an identity column and becomes part of the row key.
DETERMINISTIC = {
    "api": ["dispatches_per_round", "comm_bytes_per_round"],
    "api_sparse": ["comm_bytes_per_federation"],
    "sharded": ["dispatches_per_round", "comm_bytes_per_round"],
    "comm": ["bytes_per_federation"],
    "comm_llm": ["fedavg_bytes", "dml_dense_bytes", "dml_top64_bytes"],
    "kernels": ["derived_flops", "derived_hbm_bytes"],
    "kernels_sparse": ["derived_flops", "derived_hbm_bytes", "share_bytes"],
    "kernels_train": ["derived_flops"],
    "privacy": ["comm_bytes"],
    "privacy_dp": ["epsilon"],        # analytic accountant math — exact
    "decode": ["decode_dispatches"],  # device programs per generate call
    "decode_dispatch": ["dispatches"],
}
# machine-dependent columns: never gated, reported as info.  The privacy
# battery's accuracy/advantage columns are run-volatile (tiny synthetic
# tasks), so only their ORDERING is gated — see check_structural.
WALLCLOCK = {
    "kernels": ["us_per_call"],
    "kernels_sparse": ["us_per_call"],
    "kernels_train": ["us_per_call"],
    "sharded": ["compile_round_s", "steady_round_s"],
    "privacy": ["accuracy_pct", "mia_advantage", "epsilon"],
    "privacy_robust": ["honest_accuracy_pct"],
    "decode": ["compile_s", "steady_tok_s", "p50_ms", "p99_ms"],
}
# columns that must be truthy in the CURRENT run (parity guarantees)
MUST_BE_TRUE = {
    "api": ["bitwise_vs_legacy"],
    "decode_parity": ["ok"],
}
# wall-clock noise factor for the monotone-in-k check: a smaller-k sparse
# step may be at most this much slower than the next-larger-k one
NOISE = 1.10


def load_dir(path: str) -> Dict[str, dict]:
    out = {}
    for p in sorted(glob.glob(os.path.join(path, "BENCH_*.json"))):
        with open(p) as f:
            data = json.load(f)
        out[data["bench"]] = data
    return out


def _row_key(section: str, cols: dict) -> Tuple:
    skip = set(DETERMINISTIC.get(section, []) + WALLCLOCK.get(section, []) +
               MUST_BE_TRUE.get(section, []))
    # ratio-style strings ("3.2x") are derived, not identity
    return tuple((k, v) for k, v in cols.items()
                 if k not in skip and not str(v).endswith("x"))


def check_structural(benches: Dict[str, dict], errors: List[str]) -> None:
    """Invariants of one results dir (baseline or fresh run)."""
    for bench, data in benches.items():
        for section, rows in data.get("sections", {}).items():
            for flag in MUST_BE_TRUE.get(section, []):
                for r in rows:
                    if flag in r and not r[flag]:
                        errors.append(f"{bench}/{section}: {flag} is "
                                      f"falsy in row {_row_key(section, r)}")
            if section == "sharded":
                for r in rows:
                    ok = r.get("bitwise_vs_unsharded")
                    if ok not in (True, "ref", "True"):
                        errors.append(f"{bench}/sharded: device_count="
                                      f"{r.get('device_count')} not bitwise "
                                      f"vs unsharded ({ok!r})")
    _check_privacy(benches, errors)
    ks = benches.get("kernels", {}).get("sections", {}).get("kernels_sparse")
    if ks:
        impls = sorted({r["impl"] for r in ks})
        for impl in impls:
            dense = [r for r in ks if r["impl"] == impl
                     and r["step"] == "dense"]
            sparse = sorted((r for r in ks if r["impl"] == impl
                             and r["step"] == "sparse"),
                            key=lambda r: -int(r["k"]))
            if not dense or len(sparse) < 2:
                errors.append(f"kernels_sparse[{impl}]: missing dense row "
                              "or <2 sparse k points")
                continue
            # wire + model columns: strictly smaller at smaller k, and
            # every sparse point below the dense baseline
            for col in ("share_bytes", "derived_flops", "derived_hbm_bytes"):
                vals = [r[col] for r in sparse]
                if any(b >= a for a, b in zip(vals, vals[1:])):
                    errors.append(f"kernels_sparse[{impl}]: {col} not "
                                  f"strictly decreasing as k shrinks: {vals}")
                if any(v >= dense[0][col] for v in vals):
                    errors.append(f"kernels_sparse[{impl}]: sparse {col} "
                                  f"not below dense ({dense[0][col]})")
            # wall-clock: monotone non-increasing as k shrinks, with noise
            us = [r["us_per_call"] for r in sparse]
            kseq = [r["k"] for r in sparse]
            bad = [(ka, kb) for (ka, ua), (kb, ub)
                   in zip(zip(kseq, us), zip(kseq[1:], us[1:]))
                   if ub > ua * NOISE]
            if bad:
                errors.append(f"kernels_sparse[{impl}]: us_per_call not "
                              f"monotone as k shrinks (k pairs {bad}, "
                              f"us={us}, noise factor {NOISE})")
    dd = benches.get("decode", {}).get("sections", {})
    if dd.get("decode_dispatch"):
        # the O(1) claim: dispatches per generate must not depend on gen_len
        series: Dict[Tuple, Dict] = {}
        for r in dd["decode_dispatch"]:
            series.setdefault((r["arch"], r["models"]),
                              {})[int(r["gen_len"])] = int(r["dispatches"])
        for key, by_gl in series.items():
            if len(set(by_gl.values())) != 1:
                errors.append(f"decode_dispatch{key}: dispatches vary with "
                              f"gen_len: {by_gl} — decode is not a single "
                              "fused program")
    if dd.get("decode"):
        # batching must pay off: steady tokens/s at the largest batch must
        # beat batch=1 for at least one (arch, models) series
        gains = {}
        for r in dd["decode"]:
            gains.setdefault((r["arch"], r["models"]),
                             {})[int(r["batch"])] = float(r["steady_tok_s"])
        improved = [k for k, by_b in gains.items()
                    if len(by_b) >= 2 and by_b[max(by_b)] > by_b[min(by_b)]]
        if not improved:
            errors.append(f"decode: steady_tok_s does not improve with "
                          f"batch for ANY arch: {gains}")
    kt = benches.get("kernels", {}).get("sections", {}).get("kernels_train")
    if kt:
        # the fwd+bwd row must carry exactly 3x the forward FLOPs (6ND vs
        # 2ND) — training runs full fwd+bwd through the kernel custom VJPs
        for impl in sorted({r["impl"] for r in kt}):
            by_step = {r["step"]: r for r in kt if r["impl"] == impl}
            if set(by_step) != {"fwd", "fwd+bwd"}:
                errors.append(f"kernels_train[{impl}]: expected fwd and "
                              f"fwd+bwd rows, got {sorted(by_step)}")
                continue
            f, t = (by_step["fwd"]["derived_flops"],
                    by_step["fwd+bwd"]["derived_flops"])
            if t != 3 * f:
                errors.append(f"kernels_train[{impl}]: fwd+bwd flops {t} "
                              f"!= 3x fwd flops {f}")


def _check_privacy(benches: Dict[str, dict], errors: List[str]) -> None:
    """Ordering invariants of the privacy battery — the claims the table
    exists to make, checked on whatever run is in front of us."""
    secs = benches.get("privacy", {}).get("sections", {})
    pv = {r["strategy"]: r for r in secs.get("privacy", [])}
    if pv:
        need = {"fedavg", "dml", "dp-dml"}
        if not need <= set(pv):
            errors.append(f"privacy: missing strategies {need - set(pv)}")
        else:
            fa = float(pv["fedavg"]["mia_advantage"])
            dml = float(pv["dml"]["mia_advantage"])
            dp = float(pv["dp-dml"]["mia_advantage"])
            if fa <= dml:
                errors.append("privacy: leakage ordering violated — fedavg "
                              f"MIA advantage {fa} <= dml {dml}")
            if dp > dml + 0.1:
                errors.append("privacy: dp-dml MIA advantage "
                              f"{dp} exceeds dml {dml} beyond probe noise")
    dprows = secs.get("privacy_dp", [])
    single = sorted((r for r in dprows if int(r["releases"]) == 1),
                    key=lambda r: float(r["sigma"]))
    eps = [float(r["epsilon"]) for r in single]
    if any(b >= a for a, b in zip(eps, eps[1:])):
        errors.append("privacy_dp: epsilon not strictly decreasing in "
                      f"sigma: {eps}")
    comp = sorted((r for r in dprows if float(r["sigma"]) == 1.0
                   and int(r["releases"]) > 1),
                  key=lambda r: int(r["releases"]))
    ceps = [float(r["epsilon"]) for r in comp]
    if any(b <= a for a, b in zip(ceps, ceps[1:])):
        errors.append("privacy_dp: epsilon not increasing in composed "
                      f"releases: {ceps}")
    rb = {(r["strategy"], r["attack"]): float(r["honest_accuracy_pct"])
          for r in secs.get("privacy_robust", [])}
    if rb:
        clean = rb.get(("dml", "none"))
        pois = rb.get(("dml", "collude"))
        if clean is not None and pois is not None:
            if pois > clean - 10.0:
                errors.append("privacy_robust: colluder did not degrade "
                              f"plain dml (clean {clean} -> {pois})")
            for s in ("trimmed-dml", "median-dml"):
                acc = rb.get((s, "collude"))
                if acc is not None and acc < pois + 10.0:
                    errors.append(f"privacy_robust: {s} under attack "
                                  f"({acc}) not better than poisoned dml "
                                  f"({pois})")


def check_regression(base: Dict[str, dict], cur: Dict[str, dict],
                     tol: float, errors: List[str]) -> None:
    for bench, bdata in base.items():
        if bench not in cur:
            print(f"info: bench {bench!r} missing from current run "
                  "(not regenerated) — skipped")
            continue
        for section, brows in bdata.get("sections", {}).items():
            crows = {_row_key(section, r): r
                     for r in cur[bench]["sections"].get(section, [])}
            for br in brows:
                key = _row_key(section, br)
                cr = crows.get(key)
                if cr is None:
                    errors.append(f"{bench}/{section}: baseline row {key} "
                                  "missing from current run")
                    continue
                for col in DETERMINISTIC.get(section, []):
                    if col not in br:
                        continue
                    b, c = float(br[col]), float(cr[col])
                    if c > b * (1.0 + tol):
                        errors.append(
                            f"{bench}/{section}{key}: {col} regressed "
                            f"{b:g} -> {c:g} (> {tol:.0%})")
                for col in WALLCLOCK.get(section, []):
                    if col in br and float(br[col]) > 0:
                        d = float(cr[col]) / float(br[col]) - 1.0
                        if abs(d) > tol:
                            print(f"info: {bench}/{section}{key}: {col} "
                                  f"{br[col]} -> {cr[col]} ({d:+.0%}, "
                                  "wall-clock — not gated)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="benchmarks/results",
                    help="committed baseline dir of BENCH_*.json")
    ap.add_argument("--current", default=None,
                    help="freshly generated dir; omit to only check the "
                    "baseline's structural invariants")
    ap.add_argument("--tol", type=float, default=0.20,
                    help="allowed growth of deterministic tracked metrics")
    args = ap.parse_args(argv)
    base = load_dir(args.baseline)
    if not base:
        print(f"no BENCH_*.json under {args.baseline!r}", file=sys.stderr)
        return 1
    errors: List[str] = []
    if args.current:
        cur = load_dir(args.current)
        if not cur:
            print(f"no BENCH_*.json under {args.current!r}", file=sys.stderr)
            return 1
        check_structural(cur, errors)
        check_regression(base, cur, args.tol, errors)
    else:
        check_structural(base, errors)
    if errors:
        print(f"\nFAIL — {len(errors)} benchmark gate violation(s):")
        for e in errors:
            print(f"  - {e}")
        return 1
    n = sum(len(rows) for d in base.values()
            for rows in d.get("sections", {}).values())
    print(f"ok — {len(base)} bench table(s), {n} baseline rows, "
          + ("regression+structural gates passed"
               if args.current else "structural gates passed"))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
