"""Beyond-weight-sharing: federated mutual learning across HETEROGENEOUS
architectures — a dense transformer, an attention-free SSM, and a
fine-grained MoE learn from each other through the unified session API.
Weight averaging is impossible here (the pytrees don't even match); the
``Federation`` rejects ``FedAvg()`` on this population at construction,
while prediction sharing (``DML``) — and its bandwidth-constrained
``SparseDML(k)`` variant — just works: only the (K, N_pub, V) public-set
logits (or their top-k compression) ever cross a client boundary.

  PYTHONPATH=src python examples/dml_heterogeneous.py
"""
import numpy as np

from repro.api import (DML, Federation, HeteroClients, SparseDML,
                       make_lm_pool)

ARCHS = ("qwen3-4b", "mamba2-780m", "dbrx-132b")   # dense / ssm / moe
ROUNDS = 4

pool, labels = make_lm_pool(((1 + len(ARCHS)) * ROUNDS + 1) * 8,
                            seq_len=48, vocab=512, seed=0)
population = HeteroClients(ARCHS, pool, labels, rounds=ROUNDS,
                           local_epochs=1, batch_size=4, public_batch=4,
                           lr=3e-3, seed=0)
session = Federation(population, DML(kl_weight=2.0))

print("federating:", ", ".join(
    f"{a} ({population._models[a].family})" for a in ARCHS))
history = session.run()
for rl in history.rounds:
    print(f"round {rl.round:3d}  local={['%.3f' % x for x in rl.client_loss]}"
          f"  cross-arch kld={['%.4f' % x for x in rl.kl_loss]}"
          f"  comm_bytes={rl.comm_bytes}")

session.evaluate()
print(f"\nheld-out eval loss per client: "
      f"{['%.3f' % x for x in history.client_eval_loss]}")
print(f"total logits traffic: {history.total_comm_bytes} bytes "
      f"(vs per-round weight averaging: undefined — "
      f"client pytrees have {[f'{n:,}' for n in population.n_params]} params "
      f"and different structures)")

# the same fleet under sparse top-k sharing: V/(2k) fewer bytes
sparse = Federation(
    HeteroClients(ARCHS, pool, labels, rounds=ROUNDS, local_epochs=1,
                  batch_size=4, public_batch=4, lr=3e-3, seed=0),
    SparseDML(k=16, kl_weight=2.0))
hs = sparse.run()
print(f"\nsparse top-16 sharing: {hs.total_comm_bytes} bytes "
      f"({history.total_comm_bytes / hs.total_comm_bytes:.0f}x below dense "
      "DML; weight averaging remains undefined)")
