"""Beyond-weight-sharing: federated mutual learning across HETEROGENEOUS
architectures — a dense transformer, an attention-free SSM, and a
fine-grained MoE learn from each other.  Weight averaging is impossible
here (the pytrees don't even match); loss sharing doesn't care.  This is
the paper's §I motivation ("different IoT devices ... might use different
architectures") demonstrated at the model-family level.

  PYTHONPATH=src python examples/dml_heterogeneous.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core.mutual import mutual_kl_terms
from repro.data.synthetic import make_token_stream
from repro.models import transformer as tfm
from repro.optim import AdamWConfig, adamw_init, adamw_update

ARCHS = ["qwen3-4b", "mamba2-780m", "dbrx-132b"]   # dense / ssm / moe
B, S, STEPS = 2, 48, 12
KL_W = 2.0

cfgs = [get_reduced(a) for a in ARCHS]
V = cfgs[0].vocab_size
assert all(c.vocab_size == V for c in cfgs), "shared tokenizer/vocab required"

keys = jax.random.split(jax.random.PRNGKey(0), len(cfgs))
params = [tfm.init_model(k, c) for k, c in zip(keys, cfgs)]
opts = [adamw_init(p) for p in params]
opt_cfg = AdamWConfig(lr=3e-3, warmup=3, total_steps=STEPS)


def make_client_step(cfg):
    def client_loss(p, toks, pub, others_logits):
        loss_priv, _ = tfm.loss_fn(p, cfg, toks)
        my_logits, _ = tfm.forward(p, cfg, pub)
        stack = jnp.concatenate(
            [my_logits.reshape(1, -1, V),
             jax.lax.stop_gradient(others_logits)], axis=0)
        kl = mutual_kl_terms(stack, jax.lax.stop_gradient(stack))[0]
        return loss_priv + KL_W * jnp.mean(kl), loss_priv

    @jax.jit
    def step(p, opt, toks, pub, others_logits):
        (_, priv), grads = jax.value_and_grad(client_loss, has_aux=True)(
            p, toks, pub, others_logits)
        p2, opt2, _ = adamw_update(p, grads, opt, opt_cfg)
        return p2, opt2, priv

    @jax.jit
    def predict(p, pub):
        logits, _ = tfm.forward(p, cfg, pub)
        return logits.reshape(-1, V)
    return step, predict


clients = [make_client_step(c) for c in cfgs]

print("federating:", ", ".join(f"{a} ({c.family})"
                               for a, c in zip(ARCHS, cfgs)))
for i in range(STEPS):
    pub = jnp.asarray(make_token_stream(B, S, V, seed=9000 + i, domain=9))
    # 1) every client publishes its predictions on the public batch
    all_logits = jnp.stack([pred(p, pub)
                            for (_, pred), p in zip(clients, params)])
    # 2) each client descends Eq. 1 with the received predictions fixed
    privs = []
    for c, ((step, _), cfg) in enumerate(zip(clients, cfgs)):
        toks = jnp.asarray(make_token_stream(B, S, V, seed=100 * i + c,
                                             domain=c))
        others = jnp.delete(all_logits, c, axis=0)
        params[c], opts[c], priv = step(params[c], opts[c], toks, pub, others)
        privs.append(float(priv))
    # consensus across *different architectures*
    kl = mutual_kl_terms(all_logits, all_logits)
    if i % 3 == 0 or i == STEPS - 1:
        print(f"step {i:3d}  private={['%.3f' % p for p in privs]}  "
              f"cross-arch kld_avg={float(jnp.mean(kl)):.5f}")

print("\nweight averaging across these clients is undefined "
      "(different pytrees); prediction sharing just worked.")
