"""Beyond-weight-sharing: federated mutual learning across HETEROGENEOUS
architectures — a dense transformer, an attention-free SSM, and a
fine-grained MoE learn from each other through `repro.core.hetero`, the
engine version of the paper's §I motivation ("different IoT devices ...
might use different architectures").  Weight averaging is impossible here
(the pytrees don't even match); loss sharing doesn't care — only the
(K, N_pub, V) public-set logits ever cross a client boundary.

  PYTHONPATH=src python examples/dml_heterogeneous.py
"""
import numpy as np

from repro.core.hetero import HeteroConfig, HeteroTrainer, make_lm_pool

ARCHS = ("qwen3-4b", "mamba2-780m", "dbrx-132b")   # dense / ssm / moe
ROUNDS = 4

cfg = HeteroConfig(archs=ARCHS, rounds=ROUNDS, local_epochs=1, batch_size=4,
                   public_batch=4, lr=3e-3, kl_weight=2.0, seed=0)
pool, labels = make_lm_pool(((1 + len(ARCHS)) * ROUNDS + 1) * 8,
                            seq_len=48, vocab=512, seed=0)
trainer = HeteroTrainer(cfg, pool, labels)

print("federating:", ", ".join(
    f"{a} ({trainer._models[a].family})" for a in ARCHS))
history = trainer.run()
for rl in history.rounds:
    print(f"round {rl.round:3d}  local={['%.3f' % x for x in rl.client_loss]}"
          f"  cross-arch kld={['%.4f' % x for x in rl.kl_loss]}"
          f"  comm_bytes={rl.comm_bytes}")

trainer.evaluate()
print(f"\nheld-out eval loss per client: "
      f"{['%.3f' % x for x in history.client_eval_loss]}")
print(f"total logits traffic: {history.total_comm_bytes} bytes "
      f"(vs per-round weight averaging: undefined — "
      f"client pytrees have {[f'{n:,}' for n in trainer.n_params]} params "
      f"and different structures)")
print("\nweight averaging across these clients is undefined "
      "(different pytrees); prediction sharing just worked.")
