"""Batched serving example: continuous request loop with prefill + decode
against the ring-buffer KV / SSM cache (the decode_32k / long_500k path at
CPU scale).

  PYTHONPATH=src python examples/serve_lm.py --arch mamba2-780m
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_reduced
from repro.data.synthetic import make_token_stream
from repro.launch.serve import greedy_generate
from repro.models import transformer as tfm

ap = argparse.ArgumentParser()
ap.add_argument("--arch", choices=ARCH_IDS, default="mamba2-780m")
ap.add_argument("--requests", type=int, default=3)
ap.add_argument("--batch", type=int, default=2)
ap.add_argument("--prompt-len", type=int, default=24)
ap.add_argument("--gen", type=int, default=12)
args = ap.parse_args()

cfg = get_reduced(args.arch).replace(prefix_tokens=0, prefix_dim=0)
params = tfm.init_model(jax.random.PRNGKey(0), cfg)
print(f"serving {args.arch} (reduced), batch={args.batch}, "
      f"{args.requests} request waves")

total_tok, t0 = 0, time.time()
for r in range(args.requests):
    prompts = jnp.asarray(make_token_stream(
        args.batch, args.prompt_len, cfg.vocab_size, seed=r))
    gen = greedy_generate(cfg, params, prompts, args.gen)
    total_tok += gen.size
    print(f"  wave {r}: prompts{tuple(prompts.shape)} -> "
          f"generated{tuple(gen.shape)}  first={np.asarray(gen[0])[:8].tolist()}")
dt = time.time() - t0
print(f"served {total_tok} tokens in {dt:.1f}s ({total_tok / dt:.1f} tok/s, "
      f"jit compile included)")
