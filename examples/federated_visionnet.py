"""The paper's case study, end to end: VisionNet face-mask classification
under Algorithm 1, all three frameworks, full fold discipline, evaluation
on the unseen second dataset (paper Table II + Fig. 3/4).

This is the end-to-end training driver: 5 clients x 12 rounds x local
epochs = a few hundred optimizer steps per framework.

  PYTHONPATH=src python examples/federated_visionnet.py [--rounds 12] [--fast]
"""
import argparse

import numpy as np

from repro.configs.visionnet import CONFIG, reduced
from repro.core.federated import FederatedConfig, FederatedTrainer
from repro.data.synthetic import make_paper_datasets

ap = argparse.ArgumentParser()
ap.add_argument("--rounds", type=int, default=12)        # paper: 12
ap.add_argument("--clients", type=int, default=5)        # paper: 5
ap.add_argument("--fast", action="store_true",
                help="reduced image size + fewer rounds (CI-sized)")
args = ap.parse_args()

vn = reduced() if args.fast else reduced()  # 32px CNN; full 100px is slow on CPU
rounds = 3 if args.fast else args.rounds
clients = 3 if args.fast else args.clients
n_train, n_test = (900, 300) if args.fast else (3833, 5988)  # paper Table I

(tr_x, tr_y), (te_x, te_y) = make_paper_datasets(
    image_size=vn.image_size, n_train=n_train, n_test=n_test)
print(f"dataset1 (train): {len(tr_x)}  dataset2 (unseen test): {len(te_x)}")

results = {}
for method in ("fedavg", "async", "dml"):
    fc = FederatedConfig(method=method, n_clients=clients, rounds=rounds,
                         local_epochs=3, batch_size=16, lr=0.05,
                         delta=3, min_round=5 if not args.fast else 1)
    tr = FederatedTrainer(vn, fc, tr_x, tr_y)
    h = tr.run()
    n_disp = sum(1 for r, _ in tr.dispatch_log if 0 <= r < rounds)
    h = tr.evaluate(te_x, te_y)
    results[method] = h
    accs = " ".join(f"{100 * a:5.2f}" for a in h.client_test_acc)
    print(f"\n{method:8s} client accuracies: {accs}")
    print(f"{'':8s} round engine: {n_disp / rounds:.1f} jitted dispatches/round "
          f"(vs {clients} clients x batches in a host loop)")
    print(f"{'':8s} spread={100 * (max(h.client_test_acc) - min(h.client_test_acc)):.2f}pp "
          f"comm={h.total_comm_bytes / 1e6:.3f} MB "
          f"global_acc={100 * h.global_test_acc:.2f}")

print("\n--- paper Table II analogue (unseen dataset) ---")
print(f"{'framework':28s}" + "".join(f"client{i:d}  " for i in range(clients)))
names = {"fedavg": "Vanilla FL", "async": "Async Weight FL",
         "dml": "Mutual Learning FL (ours)"}
for m, h in results.items():
    row = "".join(f"{100 * a:7.2f}  " for a in h.client_test_acc)
    print(f"{names[m]:28s}{row}")
ratio = results["fedavg"].total_comm_bytes / max(results["dml"].total_comm_bytes, 1)
print(f"\nDML uses {ratio:.0f}x less communication than vanilla FL.")
