"""The paper's case study, end to end: VisionNet face-mask classification
under Algorithm 1, all three frameworks, full fold discipline, evaluation
on the unseen second dataset (paper Table II + Fig. 3/4).

Each framework is the SAME session with a different sharing strategy —
the unified API makes the paper's comparison axis literal:

    Federation(VisionClients(...), DML() | FedAvg() | AsyncWeights())

  PYTHONPATH=src python examples/federated_visionnet.py [--rounds 12] [--fast]
"""
import argparse

import numpy as np

from repro.api import DML, AsyncWeights, FedAvg, Federation, VisionClients
from repro.configs.visionnet import reduced
from repro.data.synthetic import make_paper_datasets

ap = argparse.ArgumentParser()
ap.add_argument("--rounds", type=int, default=12)        # paper: 12
ap.add_argument("--clients", type=int, default=5)        # paper: 5
ap.add_argument("--fast", action="store_true",
                help="reduced image size + fewer rounds (CI-sized)")
args = ap.parse_args()

vn = reduced()                 # 32px CNN; full 100px is slow on CPU
rounds = 3 if args.fast else args.rounds
clients = 3 if args.fast else args.clients
n_train, n_test = (900, 300) if args.fast else (3833, 5988)  # paper Table I

(tr_x, tr_y), (te_x, te_y) = make_paper_datasets(
    image_size=vn.image_size, n_train=n_train, n_test=n_test)
print(f"dataset1 (train): {len(tr_x)}  dataset2 (unseen test): {len(te_x)}")

strategies = {
    "fedavg": FedAvg(),
    "async": AsyncWeights(delta=3, min_round=5 if not args.fast else 1),
    "dml": DML(kl_weight=1.0, mutual_epochs=1),
}

results = {}
for name, strategy in strategies.items():
    fed = Federation(
        VisionClients(vn, tr_x, tr_y, n_clients=clients, rounds=rounds,
                      local_epochs=3, batch_size=16, lr=0.05),
        strategy)
    h = fed.run()
    n_disp = sum(1 for r, _ in fed.dispatch_log if 0 <= r < rounds)
    h = fed.evaluate(split=(te_x, te_y))
    results[name] = h
    accs = " ".join(f"{100 * a:5.2f}" for a in h.client_test_acc)
    print(f"\n{name:8s} client accuracies: {accs}")
    print(f"{'':8s} round engine: {n_disp / rounds:.1f} jitted dispatches/round "
          f"(vs {clients} clients x batches in a host loop)")
    print(f"{'':8s} spread={100 * (max(h.client_test_acc) - min(h.client_test_acc)):.2f}pp "
          f"comm={h.total_comm_bytes / 1e6:.3f} MB "
          f"global_acc={100 * h.global_test_acc:.2f}")

print("\n--- paper Table II analogue (unseen dataset) ---")
print(f"{'framework':28s}" + "".join(f"client{i:d}  " for i in range(clients)))
names = {"fedavg": "Vanilla FL", "async": "Async Weight FL",
         "dml": "Mutual Learning FL (ours)"}
for m, h in results.items():
    row = "".join(f"{100 * a:7.2f}  " for a in h.client_test_acc)
    print(f"{names[m]:28s}{row}")
ratio = results["fedavg"].total_comm_bytes / max(results["dml"].total_comm_bytes, 1)
print(f"\nDML uses {ratio:.0f}x less communication than vanilla FL.")
