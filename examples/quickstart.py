"""Quickstart: federated mutual learning across 3 LLM clients in ~a minute,
through the unified session API.

One ``Federation`` composes a sharing strategy (``DML``: clients share
only public-batch logits and descend Eq. 1 — never weights) with a client
population (``LMClients``: K reduced-LLM clients stacked on the leading
axis of every param/opt leaf, one fused jitted update per round).  Swap
the strategy — ``SparseDML(k=64)``, ``FedAvg()``, ``AsyncWeights()`` —
and nothing else changes; the session's comm ledger shows what each
choice costs on the wire.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.api import DML, Federation, LMClients
from repro.configs import get_reduced

K, STEPS = 3, 15

cfg = get_reduced("qwen3-4b")
print(f"model: {cfg.name} (reduced: {cfg.n_layers}L d={cfg.d_model}) "
      f"x {K} clients")

# each client has its own bigram domain (non-IID); the public batch is
# fresh every round ("dynamically changing test dataset", paper SIII.A)
session = Federation(
    LMClients(cfg, n_clients=K, rounds=STEPS, batch=2, seq=48, lr=3e-3),
    DML(kl_weight=2.0))
history = session.run()

for rl in history.rounds:
    if rl.round % 3 == 0 or rl.round == STEPS - 1:
        print(f"step {rl.round:3d}  private={np.mean(rl.client_loss):.4f}  "
              f"public_ce={np.mean(rl.public_ce):.4f}  "
              f"kld_avg={np.mean(rl.kl_loss):.5f}")

# the bandwidth story (paper's central claim), at this exact setup: the
# same session under weight sharing vs dense vs sparse prediction sharing
from repro.core.fedavg import comm_bytes_per_round
from repro.core.mutual import sparse_share_bytes

logit_bytes = history.rounds[-1].comm_bytes
weight_bytes = comm_bytes_per_round(
    session.population.params_per_client, K)       # what FedAvg() would move
pop = session.population  # public batch: max(1, batch//2) seqs x seq tokens
positions = max(1, pop.batch // 2) * pop.seq
sparse_bytes = sparse_share_bytes(K, positions, 64)  # what SparseDML(64) would
print(f"\nper-round sharing: DML={logit_bytes / 1e6:.2f} MB "
      f"vs FedAvg={weight_bytes / 1e6:.2f} MB "
      f"({weight_bytes / logit_bytes:.0f}x less traffic; "
      f"sparse top-64: {sparse_bytes / 1e3:.1f} kB)")
