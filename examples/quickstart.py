"""Quickstart: federated mutual learning across 3 LLM clients in ~a minute.

Three clients (reduced qwen3-4b geometry) each train on a private synthetic
domain; every step they also descend Eq. 1 on a shared public batch —
sharing only logits, never weights.

Clients live on the leading K axis of every param/opt leaf (the
``core.stacking`` layout shared by the VisionNet round engine and the
mesh-scale path), so one fused, jitted step trains all of them at once.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core import distributed as D
from repro.data.synthetic import make_token_stream
from repro.optim import AdamWConfig

K, B, S, STEPS = 3, 2, 48, 15

cfg = get_reduced("qwen3-4b")
print(f"model: {cfg.name} (reduced: {cfg.n_layers}L d={cfg.d_model}) "
      f"x {K} clients")

params = D.stacked_init(jax.random.PRNGKey(0), cfg, K)
opt = D.stacked_adamw_init(params)
step = jax.jit(D.make_dml_train_step(
    cfg, AdamWConfig(lr=3e-3, warmup=3, total_steps=STEPS), kl_weight=2.0))

for i in range(STEPS):
    # each client has its own domain (non-IID); the public batch is fresh
    # every round ("dynamically changing test dataset", paper SIII.A)
    private = jnp.stack([
        jnp.asarray(make_token_stream(B, S, cfg.vocab_size,
                                      seed=100 * i + d, domain=d))
        for d in range(K)])
    public = jnp.asarray(make_token_stream(B, S, cfg.vocab_size,
                                           seed=7000 + i, domain=K))
    params, opt, m = step(params, opt, private, public)
    if i % 3 == 0 or i == STEPS - 1:
        print(f"step {i:3d}  private={np.mean(m['private_loss']):.4f}  "
              f"public_ce={np.mean(m['public_ce']):.4f}  "
              f"kld_avg={np.mean(m['kld_avg']):.5f}")

# the bandwidth story (paper's central claim), at this exact setup:
n_params = cfg.param_count()
logit_bytes = 2 * K * B * S * cfg.vocab_size * 4
weight_bytes = 2 * K * n_params * 4
print(f"\nper-round sharing: DML={logit_bytes / 1e6:.2f} MB "
      f"vs FedAvg={weight_bytes / 1e6:.2f} MB "
      f"({weight_bytes / logit_bytes:.0f}x less traffic)")
