"""Serving subsystem: continuous-batching ensemble inference over trained
Federations.  See docs/SERVING.md for the architecture."""
from repro.serve.cache import batch_axis, init_arena, write_slot
from repro.serve.engine import MODES, ServeEngine
from repro.serve.ensemble import (combine_logits, load_serving_params,
                                  make_router, prompt_ce)
from repro.serve.scheduler import Request, SlotScheduler

__all__ = [
    "MODES", "ServeEngine", "SlotScheduler", "Request",
    "batch_axis", "init_arena", "write_slot",
    "combine_logits", "load_serving_params", "make_router", "prompt_ce",
]
