"""Continuous batching: a request queue + slot-based bookkeeping.

The scheduler is pure host-side state — it never touches device arrays.
The engine asks it which slot to admit the next queued request into and
tells it which tokens each slot emitted; the scheduler tracks per-slot
request identity, emitted counts and budgets, and retires requests the
moment their budget is spent.  Slot lifecycle:

    FREE --admit(prefill + slot write)--> ACTIVE --budget spent--> FREE

Admission and retirement happen MID-FLIGHT: the engine decodes the whole
arena in fixed-shape chunks, and between chunks the scheduler frees
finished slots and refills them from the queue, so one jitted decode
program serves heterogeneous in-flight requests (different prompt
lengths, depths, and budgets) with no recompilation.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


@dataclass
class Request:
    """One generation request.  ``tokens`` is the raw prompt (S0,) int32;
    ``prefix`` the optional (P, prefix_dim) frontend embedding for
    prefix-token archs; ``max_new`` the generation budget."""
    rid: int
    tokens: np.ndarray
    max_new: int
    prefix: Optional[np.ndarray] = None


@dataclass
class _Slot:
    req: Request
    emitted: List[int] = field(default_factory=list)


class SlotScheduler:
    """FIFO admission over a fixed number of slots."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self.queue: deque = deque()
        self.slots: List[Optional[_Slot]] = [None] * n_slots
        self.done: Dict[int, np.ndarray] = {}
        self._next_rid = 0

    # -- submission -------------------------------------------------------
    def submit(self, tokens, max_new: int, prefix=None) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, np.asarray(tokens, np.int32),
                                  int(max_new),
                                  None if prefix is None
                                  else np.asarray(prefix, np.float32)))
        return rid

    # -- state queries ----------------------------------------------------
    @property
    def idle(self) -> bool:
        return not self.queue and all(s is None for s in self.slots)

    def free_slots(self) -> List[int]:
        return [b for b, s in enumerate(self.slots) if s is None]

    def active_slots(self) -> List[int]:
        return [b for b, s in enumerate(self.slots) if s is not None]

    def next_request(self) -> Optional[Request]:
        return self.queue[0] if self.queue else None

    # -- lifecycle --------------------------------------------------------
    def admit(self, slot: int) -> Request:
        """Bind the head-of-queue request to a free slot."""
        assert self.slots[slot] is None, f"slot {slot} is occupied"
        req = self.queue.popleft()
        self.slots[slot] = _Slot(req)
        return req

    def record(self, slot: int, tokens: np.ndarray) -> bool:
        """Credit a chunk of emitted tokens to a slot; tokens past the
        request's budget (a retirement mid-chunk) are dropped.  Returns
        True when the request finished and the slot is now free."""
        st = self.slots[slot]
        assert st is not None, f"slot {slot} is free"
        take = min(len(tokens), st.req.max_new - len(st.emitted))
        st.emitted.extend(int(t) for t in tokens[:take])
        if len(st.emitted) >= st.req.max_new:
            self.done[st.req.rid] = np.asarray(st.emitted, np.int32)
            self.slots[slot] = None
            return True
        return False
