"""The batched serving engine: continuous batching + jitted multi-step
decode over trained ``Federation`` populations.

One ``ServeEngine`` owns a fixed-shape cache arena (``serve.cache``), a
host-side slot scheduler (``serve.scheduler``), and a small set of jitted
programs cached by shape:

  prefill[S0]      prompt ingestion at the request's prompt length
                   (compiled once per DISTINCT length, not per request)
  router[S0]       route mode only: per-client prompt CE -> argmin client
  first_token      sample the first emission from the prefill logits
  decode[T]        T decode steps in ONE program — ``lax.scan`` over
                   tokens with in-place ring/SSM cache updates; in
                   ensemble modes each step vmaps the K stacked clients
                   and samples from the combined logits

so the number of device dispatches for a generation is CONSTANT in
``gen_len`` (``generate``: prefill + first_token + one decode scan), and
the continuous-batching loop (``submit``/``run``) re-dispatches the SAME
compiled ``decode[chunk]`` program between admissions — requests join and
retire mid-flight with zero recompilation.

Sampling: ``temperature``/``top_k`` are engine-level trace-time constants
(greedy == ``temperature=0`` is the exact-argmax special case); the PRNG
key is split once per step inside the scan, so a fixed ``seed`` makes
every schedule deterministic and chunked decodes chain bit-identically
with one longer scan.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.launch.steps import sample_token
from repro.models import transformer as tfm
from repro.serve import cache as cache_mod
from repro.serve.ensemble import (combine_logits, load_serving_params,
                                  make_router)
from repro.serve.scheduler import SlotScheduler

MODES = ("single", "average", "route")


class ServeEngine:
    """Serve one model or a stacked K-client ensemble.

    ``params``: a plain model pytree (``mode='single'``) or the stacked
    (K, ...) client pytree of a trained LM population (ensemble modes).
    ``slots`` x ``max_seq`` fixes the arena shape — every admitted
    request must satisfy ``prefix + len(prompt) + max_new <= max_seq``.
    """

    def __init__(self, cfg: ModelConfig, params, *, mode: str = "single",
                 slots: int = 4, max_seq: int = 128,
                 window: Optional[int] = None, temperature: float = 0.0,
                 top_k: int = 0, chunk: int = 8, seed: int = 0):
        if mode not in MODES:
            raise ValueError(f"mode {mode!r} not in {MODES}")
        lead = jax.tree.leaves(params)[0].ndim
        stacked = mode != "single"
        if stacked:
            ks = {int(x.shape[0]) for x in jax.tree.leaves(params)}
            if len(ks) != 1:
                raise ValueError(
                    f"ensemble mode {mode!r} needs params stacked on a "
                    f"uniform leading client axis, got sizes {sorted(ks)}")
        del lead
        self.cfg = cfg
        self.params = params
        self.mode = mode
        self.n_models = (int(jax.tree.leaves(params)[0].shape[0])
                         if stacked else 1)
        self.slots = slots
        self.max_seq = max_seq
        self.window = window
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self.chunk = int(chunk)
        self.seed = seed
        self.scheduler = SlotScheduler(slots)
        self.dispatch_log: List[str] = []     # one entry per device program
        self._progs: dict = {}
        self._arena = None
        self._tok = self._pos = self._cidx = self._key = None

    @classmethod
    def from_checkpoint(cls, path: str, *, mode: str = "average",
                        client: int = 0, **kw) -> "ServeEngine":
        """Build an engine straight from a training checkpoint (the
        ``Federation`` LM population's ``save_state`` /
        ``export_for_serving`` file, or a single-model ``--save`` file).
        ``mode='single'`` serves ``client`` of the stacked population."""
        cfg, params, n_clients = load_serving_params(path)
        if mode == "single":
            params = jax.tree.map(lambda t: jnp.asarray(t)[client], params)
        else:
            params = jax.tree.map(jnp.asarray, params)
        eng = cls(cfg, params, mode=mode, **kw)
        eng.n_checkpoint_clients = n_clients
        return eng

    # -- jitted programs (shape-cached) -----------------------------------
    def _call(self, name, fn, *args):
        self.dispatch_log.append(name)
        return fn(*args)

    @property
    def _prefix_P(self) -> int:
        return self.cfg.prefix_tokens if self.cfg.prefix_tokens else 0

    def _raw_decode(self, params, tok, cache, pos):
        """One decode step -> ((K,) B, V) logits + updated cache; ensemble
        modes vmap the stacked client axis (token/pos shared)."""
        if self.mode == "single":
            return tfm.decode_step(params, self.cfg, tok, cache, pos,
                                   window=self.window)
        return jax.vmap(lambda p, c: tfm.decode_step(
            p, self.cfg, tok, c, pos, window=self.window))(params, cache)

    def _combine(self, logits, client_idx):
        if self.mode == "single":
            return logits
        return combine_logits(
            logits, "average" if self.mode == "average" else "route",
            client_idx)

    def _prefill_prog(self):
        if "prefill" not in self._progs:
            def pre(params, prompts, prefix):
                if self.mode == "single":
                    return tfm.prefill(params, self.cfg, prompts, prefix,
                                       max_seq=self.max_seq,
                                       window=self.window)
                return jax.vmap(lambda p: tfm.prefill(
                    p, self.cfg, prompts, prefix, max_seq=self.max_seq,
                    window=self.window))(params)
            self._progs["prefill"] = jax.jit(pre)
        return self._progs["prefill"]

    def _router_prog(self):
        if "router" not in self._progs:
            self._progs["router"] = jax.jit(make_router(self.cfg))
        return self._progs["router"]

    def _first_token_prog(self):
        if "first" not in self._progs:
            def first(logits, client_idx, key):
                comb = self._combine(logits, client_idx)
                return sample_token(comb, key, self.temperature,
                                    self.top_k), comb
            self._progs["first"] = jax.jit(first)
        return self._progs["first"]

    def _decode_prog(self, gen_len: int):
        key = ("decode", gen_len)
        if key not in self._progs:
            def step(params, token, cache, pos, prng, client_idx):
                def body(carry, _):
                    tok, cache, p, k = carry
                    logits, cache = self._raw_decode(params, tok, cache, p)
                    comb = self._combine(logits, client_idx)
                    k, sub = jax.random.split(k)
                    nxt = sample_token(comb, sub, self.temperature,
                                       self.top_k)
                    return (nxt[:, None], cache, p + 1, k), (tok[:, 0], comb)
                (tok, cache, pos, prng), (toks, logits) = jax.lax.scan(
                    body, (token, cache, pos, prng), None, length=gen_len)
                return (toks.T, logits.transpose(1, 0, 2), cache, tok, pos,
                        prng)
            self._progs[key] = jax.jit(step)
        return self._progs[key]

    def oracle_step(self, tok, cache, pos, client_idx=None):
        """The UN-fused one-step reference the bench gates against: the
        same vmapped per-client decode + ``combine_logits`` expression,
        dispatched standalone instead of inside the decode scan."""
        logits, cache = self._raw_decode(self.params, tok, cache, pos)
        return self._combine(logits, client_idx), cache

    # -- one-shot batch API (O(1) dispatches in gen_len) ------------------
    def generate(self, prompts, gen_len: int, prefix=None,
                 seed: Optional[int] = None, return_logits: bool = False):
        """Generate ``gen_len`` tokens for a fixed prompt batch (B, S0).

        Exactly prefill + first_token + one multi-step decode scan
        (+ router in route mode) — the dispatch count does not depend on
        ``gen_len``.  Greedy (temperature=0) output is token-identical
        to the legacy per-token Python loop.
        """
        prompts = jnp.asarray(prompts, jnp.int32)
        B, S0 = prompts.shape
        P = self._prefix_P
        if P + S0 + gen_len > self.max_seq:
            raise ValueError(f"prefix {P} + prompt {S0} + gen {gen_len} "
                             f"exceeds max_seq {self.max_seq}")
        cidx = jnp.zeros((B,), jnp.int32)
        if self.mode == "route":
            cidx, _ = self._call("router", self._router_prog(),
                                 self.params, prompts, prefix)
        logits, cache = self._call("prefill", self._prefill_prog(),
                                   self.params, prompts, prefix)
        key = jax.random.PRNGKey(self.seed if seed is None else seed)
        key, sub = jax.random.split(key)
        tok0, _ = self._call("first_token", self._first_token_prog(),
                             logits, cidx, sub)
        toks, lg, _, _, _, _ = self._call(
            "decode", self._decode_prog(gen_len), self.params,
            tok0[:, None], cache, jnp.int32(P + S0), key, cidx)
        if return_logits:
            return np.asarray(toks), np.asarray(lg)
        return np.asarray(toks)

    # -- continuous batching ----------------------------------------------
    def submit(self, tokens, max_new: int, prefix=None) -> int:
        """Queue one request; returns its request id."""
        tokens = np.asarray(tokens, np.int32)
        if tokens.ndim != 1 or not len(tokens):
            raise ValueError("submit takes a single 1-D prompt")
        P = self._prefix_P
        if P + len(tokens) + max_new > self.max_seq:
            raise ValueError(f"prefix {P} + prompt {len(tokens)} + max_new "
                             f"{max_new} exceeds max_seq {self.max_seq}")
        if P and prefix is None:
            raise ValueError(f"{self.cfg.name} needs a (P, prefix_dim) "
                             "prefix embedding per request")
        return self.scheduler.submit(tokens, max_new, prefix)

    def _ensure_arena(self):
        if self._arena is None:
            self._arena = cache_mod.init_arena(
                self.cfg, self.slots, self.max_seq, window=self.window,
                n_models=self.n_models if self.mode != "single" else 0)
            self._tok = jnp.zeros((self.slots, 1), jnp.int32)
            self._pos = jnp.zeros((self.slots,), jnp.int32)
            self._cidx = jnp.zeros((self.slots,), jnp.int32)
            self._key = jax.random.PRNGKey(self.seed)

    def _admit(self, slot: int) -> None:
        req = self.scheduler.admit(slot)
        prompts = jnp.asarray(req.tokens)[None]
        prefix = (None if req.prefix is None
                  else jnp.asarray(req.prefix)[None])
        ci = jnp.zeros((1,), jnp.int32)
        if self.mode == "route":
            ci, _ = self._call("router", self._router_prog(),
                               self.params, prompts, prefix)
        logits, one = self._call("prefill", self._prefill_prog(),
                                 self.params, prompts, prefix)
        self._key, sub = jax.random.split(self._key)
        tok0, _ = self._call("first_token", self._first_token_prog(),
                             logits, ci, sub)
        axis = cache_mod.batch_axis(
            self.n_models if self.mode != "single" else 0)
        self._arena = cache_mod.write_slot(self._arena, one,
                                           jnp.int32(slot), axis=axis)
        b = jnp.int32(slot)
        self._tok = self._tok.at[b, 0].set(tok0[0])
        self._pos = self._pos.at[b].set(self._prefix_P + len(req.tokens))
        self._cidx = self._cidx.at[b].set(ci[0])

    def run(self) -> Dict[int, np.ndarray]:
        """Drain the queue with continuous batching: admit into free
        slots, decode the whole arena for ``chunk`` steps in one
        dispatch, credit/retire, repeat.  Returns {rid: (n,) tokens}."""
        self._ensure_arena()
        sched = self.scheduler
        while not sched.idle:
            for b in sched.free_slots():
                if sched.next_request() is None:
                    break
                self._admit(b)
            active = sched.active_slots()
            toks, _, self._arena, self._tok, self._pos, self._key = \
                self._call("decode", self._decode_prog(self.chunk),
                           self.params, self._tok, self._arena, self._pos,
                           self._key, self._cidx)
            toks = np.asarray(toks)
            for b in active:
                sched.record(b, toks[b])
        out, sched.done = dict(sched.done), {}
        return out

    # -- introspection ----------------------------------------------------
    def dispatch_counts(self) -> Dict[str, int]:
        return {n: self.dispatch_log.count(n)
                for n in sorted(set(self.dispatch_log))}
