"""Slot arena: the fixed-shape KV/SSM cache the serving engine decodes in.

The arena is one cache pytree at a FIXED (slots, max_seq) shape — ensemble
modes add a leading ``n_models`` axis — so the multi-step decode program
compiles once and every admission/retirement is a slot write, never a
reshape.  Attention layers hold a ring buffer of ``min(window, max_seq)``
keys with absolute positions (unwritten entries are -1 and masked out);
Mamba layers hold constant-size (conv, ssm) state.  Both are fully
overwritten by ``write_slot`` at admission, so a retired request leaves
nothing behind for the slot's next tenant.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm


def batch_axis(n_models: int) -> int:
    """Axis carrying the slot (batch) dimension in every arena leaf: cache
    leaves are (n_periods, B, ...), plus a leading client axis when the
    engine serves an ensemble."""
    return 2 if n_models else 1


def init_arena(cfg: ModelConfig, slots: int, max_seq: int,
               window: Optional[int] = None, n_models: int = 0):
    """Empty arena: ``n_models`` = 0 means a single model (no client axis);
    otherwise every leaf gains a leading stacked-client axis."""
    one = tfm.init_cache(cfg, slots, max_seq, window=window)
    if not n_models:
        return one
    return jax.tree.map(
        lambda t: jax.numpy.broadcast_to(t, (n_models,) + t.shape).copy(),
        one)


@functools.partial(jax.jit, static_argnames=("axis",))
def write_slot(arena, one, slot, *, axis: int = 1):
    """Insert a freshly prefilled single-request cache into arena slot
    ``slot`` (traced — ONE compiled program serves every slot index).

    ``one`` is the same pytree with a size-1 batch axis (a B=1 prefill);
    ``axis`` is the arena's batch axis (``batch_axis(n_models)``).
    """
    def put(a, o):
        return jax.lax.dynamic_update_index_in_dim(
            a, jax.lax.index_in_dim(o, 0, axis, keepdims=False), slot, axis)
    return jax.tree.map(put, arena, one)
