"""Ensemble inference over a trained DML client population.

The paper's deployable artifact is the POPULATION: K mutually-distilled
clients whose predictions were the only thing that ever crossed client
boundaries during training.  Two ways to serve them:

  - ``average``: every decode step runs all K clients (vmap over the
    stacked client axis) and samples from the MEAN of their logits —
    the serving-time analogue of the Eq.-2 consensus target.
  - ``route``: pick ONE client per request — the one whose loss profile
    is nearest the prompt's domain.  Each client optimised the same Eq.-1
    objective on a shared public set but local data from its own domain,
    so per-client prompt cross-entropy IS the trained loss profile; the
    router scores the prompt under all K clients (one vmapped program)
    and binds the request's slot to the argmin client.

Checkpoint -> serving: ``load_serving_params`` reads any ``Federation``
``save_state`` file from the LM population (or the slim
``Federation.export_for_serving`` artifact, or a single-model
``launch.train --save`` file) back into (config, stacked params, K).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import checkpoint
from repro.configs import ARCH_IDS, get_reduced
from repro.configs.base import ModelConfig
from repro.models import transformer as tfm


def prompt_ce(params, cfg: ModelConfig, tokens, prefix=None) -> jax.Array:
    """Per-SEQUENCE next-token CE of a prompt batch (B, S) -> (B,).

    The routing score: teacher-forced prompt cross-entropy under one
    client (same label alignment as ``tfm.loss_fn``, kept per row
    instead of batch-averaged so each request routes independently).
    """
    logits, _ = tfm.forward(params, cfg, tokens, prefix, remat=False)
    P = cfg.prefix_tokens if cfg.prefix_tokens else 0
    if P:
        pred, labels = logits[:, P - 1: -1], tokens
    else:
        pred, labels = logits[:, :-1], tokens[:, 1:]
    logp = jax.nn.log_softmax(pred.astype(jnp.float32), axis=-1)
    ce = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(ce, axis=-1)


def make_router(cfg: ModelConfig):
    """Vmapped routing program: (stacked params, prompts (B, S)[, prefix])
    -> (client_idx (B,), ce (K, B)).  One dispatch per admission batch."""
    def route(stacked_params, prompts, prefix=None):
        ce = jax.vmap(lambda p: prompt_ce(p, cfg, prompts, prefix))(
            stacked_params)                                    # (K, B)
        return jnp.argmin(ce, axis=0).astype(jnp.int32), ce
    return route


def combine_logits(logits: jax.Array, mode: str,
                   client_idx: Optional[jax.Array] = None) -> jax.Array:
    """(K, B, V) per-client logits -> (B, V) served logits.

    ``average`` is the vmapped-oracle mean (``jnp.mean`` over the client
    axis — the bench gate holds the engine's fused path bitwise-equal to
    this expression); ``route`` selects each slot's bound client."""
    if mode == "average":
        return jnp.mean(logits, axis=0)
    if mode == "route":
        return logits[client_idx, jnp.arange(logits.shape[1])]
    raise ValueError(f"unknown ensemble mode {mode!r}")


# ---------------------------------------------------------------------------
# checkpoint -> serving

def load_serving_params(path: str) -> Tuple[ModelConfig, dict, int]:
    """Read a training checkpoint into serving shape.

    Accepts (a) ``Federation.save_state`` files from the LM population,
    (b) the slim ``Federation.export_for_serving`` artifact, and
    (c) single-model ``launch.train --save`` files.  Returns
    ``(cfg, params, n_clients)`` — params carry a leading stacked-client
    axis when ``n_clients`` > 1 (n_clients == 1 may still be stacked;
    the engine squeezes it for single-model serving).

    Hetero populations checkpoint one pytree PER ARCH — there is no
    stacked axis to vmap over, so they are rejected here (route-style
    serving across mixed archs needs one engine per arch).
    """
    state, meta = checkpoint.restore(path)
    engine = meta.get("engine")
    if engine not in (None, "lm"):
        raise ValueError(
            f"checkpoint engine {engine!r} is not servable: the serving "
            "engine needs same-arch clients stacked on a leading axis "
            "(the LM population / export_for_serving artifacts)")
    arch = meta.get("arch")
    if arch not in ARCH_IDS:
        raise ValueError(f"checkpoint arch {arch!r} not in {ARCH_IDS}")
    cfg = get_reduced(arch)
    if isinstance(state, dict) and "client_params" in state:
        params = state["client_params"]
        n_clients = int(meta.get("n_clients", 0) or
                        jax.tree.leaves(params)[0].shape[0])
    else:                       # single-model launch.train --save file
        params, n_clients = state, 1
        if "embed" not in state:
            raise ValueError(f"unrecognised checkpoint schema in {path!r}")
        params = jax.tree.map(lambda t: t[None], params)   # stack of 1
    return cfg, params, n_clients
