"""Qwen1.5-110B — dense, QKV bias [hf:Qwen/Qwen1.5 family]."""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=49_152,
    vocab_size=152_064,
    qkv_bias=True,
    period=(LayerSpec("attn", "mlp"),),
    rope_theta=1_000_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512,
        param_dtype="float32", compute_dtype="float32",
    )
