"""Config registry: ``get_config(arch_id)`` / ``get_reduced(arch_id)``.

Arch ids use the assignment's hyphenated names (``--arch dbrx-132b``).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig  # noqa: F401

_MODULES: Dict[str, str] = {
    "dbrx-132b": "dbrx_132b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "qwen3-8b": "qwen3_8b",
    "minitron-4b": "minitron_4b",
    "musicgen-medium": "musicgen_medium",
    "mamba2-780m": "mamba2_780m",
    "qwen3-4b": "qwen3_4b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "qwen1.5-110b": "qwen1_5_110b",
}

ARCH_IDS: List[str] = list(_MODULES)


def _module(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_reduced(arch: str) -> ModelConfig:
    return _module(arch).reduced()


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]
