"""Minitron-4B — width-pruned Nemotron-4 [arXiv:2407.14679]."""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9_216,
    vocab_size=256_000,
    period=(LayerSpec("attn", "mlp"),),
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=120, n_heads=3, n_kv_heads=1, head_dim=40,
        d_ff=256, vocab_size=512,
        param_dtype="float32", compute_dtype="float32",
    )
