"""DBRX-base 132B — fine-grained MoE, 16 experts top-4 [hf:databricks/dbrx-base]."""
from repro.configs.base import LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=0,                       # pure-MoE FFN
    vocab_size=100_352,
    period=(LayerSpec("attn", "moe"),),
    moe=MoEConfig(n_experts=16, top_k=4, d_expert=10_752),
    rope_theta=500_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)


def reduced() -> ModelConfig:
    """Smoke-test variant: same family/features, tiny geometry."""
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        vocab_size=512,
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=160),
        param_dtype="float32", compute_dtype="float32",
    )
