"""VisionNet — the paper's own CNN case-study model (Fig. 2).

3 conv layers (first two followed by 2x2 max-pool), dropout, dense-64,
dropout, sigmoid head; input 100x100x3, binary face-mask classification.
"""
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class VisionNetConfig:
    name: str = "visionnet"
    image_size: int = 100
    channels: int = 3
    conv_features: Tuple[int, ...] = (32, 64, 128)
    kernel_size: int = 3
    dense_features: int = 64
    dropout_rate: float = 0.5
    n_classes: int = 1            # sigmoid binary head (paper §III.B.2)

    def replace(self, **kw):
        import dataclasses
        return dataclasses.replace(self, **kw)


CONFIG = VisionNetConfig()


def reduced() -> VisionNetConfig:
    """Fast CPU variant for tests/benchmarks (same topology, 32px)."""
    return CONFIG.replace(image_size=32, conv_features=(8, 16, 32),
                          dense_features=32)
