"""Model/config dataclasses shared by every architecture.

A ``ModelConfig`` fully describes one decoder backbone: geometry, the
per-period layer program (for hybrid interleaves), MoE/SSM sub-configs, and
modality frontend stubs.  ``ShapeConfig`` describes one assigned input shape.
Configs are frozen dataclasses so they hash and can key jit caches.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# sub-configs


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN config (capacity-based top-k routing)."""

    n_experts: int
    top_k: int
    d_expert: int                 # per-expert hidden size
    n_shared_experts: int = 0     # always-on shared experts (qwen2-moe style)
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3   # router z-loss (Zoph et al.)
    aux_coef: float = 1e-2        # load-balance aux loss


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD config."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64            # SSD head dim (P)
    n_groups: int = 1             # B/C groups
    chunk: int = 256              # SSD chunk length
    dt_min: float = 0.001
    dt_max: float = 0.1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class LayerSpec:
    """One slot in the per-period layer program."""

    mixer: str                    # 'attn' | 'mamba'
    ffn: str                      # 'mlp' | 'moe' | 'none'


# ---------------------------------------------------------------------------
# main config


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                  # query heads (0 for attn-free)
    n_kv_heads: int
    d_ff: int                     # dense-MLP hidden (0 if none / pure MoE)
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    # attention features
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: Optional[int] = None   # native SWA (tokens)
    rope_theta: float = 10_000.0
    # layer program: one period, tiled n_layers // len(period) times
    period: Tuple[LayerSpec, ...] = (LayerSpec("attn", "mlp"),)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # modality frontend stub: number of prefix embedding tokens fed by the
    # (stubbed) vision/audio encoder; 0 for pure text
    prefix_tokens: int = 0
    prefix_dim: int = 0           # raw frontend embedding dim (projected to d_model)
    # long-context policy: 'native' (sub-quadratic already), 'sliding_window'
    # (use SWA variant for long_500k), or 'skip'
    long_context_variant: str = "sliding_window"
    long_context_window: int = 8192
    # norms / misc
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    # dtypes (strings so the dataclass stays hashable)
    param_dtype: str = "float32"
    compute_dtype: str = "float32"

    # -- derived ----------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def n_periods(self) -> int:
        assert self.n_layers % len(self.period) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by period "
            f"{len(self.period)}")
        return self.n_layers // len(self.period)

    @property
    def attn_free(self) -> bool:
        return all(s.mixer != "attn" for s in self.period)

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter counting (used by roofline + comm accounting) ----------
    def param_count(self) -> int:
        """Exact parameter count of the backbone built by models/transformer.py."""
        d, hd = self.d_model, self.head_dim_
        n = 0
        n += self.vocab_size * d                       # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d                   # lm head
        n += d                                          # final norm
        for spec in self.period:
            ln = 0
            ln += d                                     # pre-mixer norm
            if spec.mixer == "attn":
                qkv_out = (self.n_heads + 2 * self.n_kv_heads) * hd
                ln += d * qkv_out
                if self.qkv_bias:
                    ln += qkv_out
                if self.qk_norm:
                    ln += 2 * hd
                ln += self.n_heads * hd * d             # o_proj
            else:  # mamba
                s = self.ssm
                di = s.d_inner(d)
                nh = s.n_heads(d)
                conv_ch = di + 2 * s.n_groups * s.d_state
                ln += d * (2 * di + 2 * s.n_groups * s.d_state + nh)  # in_proj
                ln += s.d_conv * conv_ch + conv_ch      # conv1d w+b
                ln += nh                                # A_log
                ln += nh                                # D
                ln += nh                                # dt_bias
                ln += di                                # ssd norm (gated rmsnorm)
                ln += di * d                            # out_proj
            if spec.ffn != "none":
                ln += d                                 # pre-ffn norm
            if spec.ffn == "mlp":
                ln += 3 * d * self.d_ff                 # swiglu
            elif spec.ffn == "moe":
                m = self.moe
                ln += d * m.n_experts                   # router
                ln += m.n_experts * 3 * d * m.d_expert
                if m.n_shared_experts:
                    ln += 3 * d * (m.n_shared_experts * m.d_expert)
            n += ln * self.n_periods
        if self.prefix_tokens:
            n += self.prefix_dim * d + d               # projector
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        dense_experts = self.param_count()
        # subtract inactive routed experts
        n_moe_layers = sum(1 for s in self.period if s.ffn == "moe") * self.n_periods
        per_expert = 3 * self.d_model * m.d_expert
        inactive = n_moe_layers * (m.n_experts - m.top_k) * per_expert
        return dense_experts - inactive


# ---------------------------------------------------------------------------
# input shapes


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
