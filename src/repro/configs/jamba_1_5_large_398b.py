"""Jamba-1.5-Large 398B — hybrid Mamba+attention 1:7 interleave, MoE every
2nd layer, 16 experts top-2 [arXiv:2403.19887].

Period of 8 layers: attention at slot 4 (as in the Jamba paper's block),
Mamba elsewhere; MoE on odd slots (e=2), dense SwiGLU on even slots.
"""
from repro.configs.base import LayerSpec, ModelConfig, MoEConfig, SSMConfig


def _period():
    slots = []
    for i in range(8):
        mixer = "attn" if i == 4 else "mamba"
        ffn = "moe" if i % 2 == 1 else "mlp"
        slots.append(LayerSpec(mixer, ffn))
    return tuple(slots)


CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24_576,
    vocab_size=65_536,
    period=_period(),
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=24_576),
    ssm=SSMConfig(d_state=128, head_dim=64, n_groups=1, chunk=256),
    long_context_variant="native",   # only 9/72 layers are attention
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=8, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512,
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=256),
        ssm=SSMConfig(d_state=16, head_dim=32, chunk=32),
        param_dtype="float32", compute_dtype="float32",
    )
