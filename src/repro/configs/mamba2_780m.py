"""Mamba2-780m — attention-free SSD (state-space duality) [arXiv:2405.21060]."""
from repro.configs.base import LayerSpec, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,                       # mamba2 blocks carry no MLP
    vocab_size=50_280,
    period=(LayerSpec("mamba", "none"),),
    ssm=SSMConfig(d_state=128, head_dim=64, n_groups=1, chunk=256),
    long_context_variant="native",
    tie_embeddings=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, vocab_size=512,
        ssm=SSMConfig(d_state=16, head_dim=32, chunk=32),
        param_dtype="float32", compute_dtype="float32",
    )
