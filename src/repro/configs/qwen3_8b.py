"""Qwen3-8B — dense, qk-norm, GQA [hf:Qwen/Qwen3-8B]."""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12_288,
    vocab_size=151_936,
    qk_norm=True,
    period=(LayerSpec("attn", "mlp"),),
    rope_theta=1_000_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512,
        param_dtype="float32", compute_dtype="float32",
    )
