"""Qwen1.5-MoE-A2.7B — 60 routed experts top-4 + 4 shared
[hf:Qwen/Qwen1.5-MoE-A2.7B]."""
from repro.configs.base import LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=0,                       # pure-MoE FFN (shared experts cover dense path)
    vocab_size=151_936,
    qkv_bias=True,                # qwen1.5 lineage keeps QKV bias
    period=(LayerSpec("attn", "moe"),),
    moe=MoEConfig(n_experts=60, top_k=4, d_expert=1408, n_shared_experts=4),
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        vocab_size=512,
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=96, n_shared_experts=1),
        param_dtype="float32", compute_dtype="float32",
    )
