"""MusicGen-medium — decoder-only LM over EnCodec tokens [arXiv:2306.05284].

The EnCodec tokenizer/conv codec and the T5 text-conditioning encoder are the
stubbed modality frontend: ``input_specs`` feeds (a) EnCodec token ids in the
2048-entry codebook vocabulary (codebook interleaving via the delay pattern is
a data-layout choice, already applied upstream) and (b) a conditioning prefix
of precomputed text-encoder embeddings.  kv_heads == n_heads (plain MHA).
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6_144,
    vocab_size=2_048,
    period=(LayerSpec("attn", "mlp"),),
    prefix_tokens=64,             # conditioning embeddings (stub frontend)
    prefix_dim=768,               # T5-base hidden
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab_size=256, prefix_tokens=8, prefix_dim=48,
        param_dtype="float32", compute_dtype="float32",
    )
