"""LLaVA-NeXT (Mistral-7B backbone) — anyres tiling VLM
[hf:llava-hf/llava-v1.6-mistral-7b-hf].

The vision tower (CLIP-ViT-L/336 + 2-layer MLP projector, anyres tiling up to
5 tiles x 576 patches) is the stubbed modality frontend: ``input_specs`` feeds
precomputed patch embeddings of shape (B, prefix_tokens, prefix_dim) and the
backbone owns only the projector + decoder.  Mistral-7B uses native sliding-
window attention (4096), so long_500k runs natively sub-quadratic.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=32_000,
    sliding_window=4096,          # mistral-7B-v0.1 native SWA
    period=(LayerSpec("attn", "mlp"),),
    rope_theta=10_000.0,
    prefix_tokens=2880,           # anyres: 5 tiles x 576 patches
    prefix_dim=1024,              # CLIP-ViT-L hidden
    long_context_variant="native",
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512, sliding_window=64,
        prefix_tokens=16, prefix_dim=48,
        param_dtype="float32", compute_dtype="float32",
    )
