"""The stable public API of the reproduction — one import site for the
strategy-composable session layer:

    from repro.api import Federation, VisionClients, DML

    session = Federation(
        VisionClients(vn_cfg, train_x, train_y, n_clients=5, rounds=12),
        DML(kl_weight=1.0, mutual_epochs=1))
    session.run()
    session.evaluate(split=(test_x, test_y))

Strategies (what crosses the wire) and populations (who federates, on
which execution backend) compose freely where the math is defined; a
population rejects an impossible pairing at construction (e.g. weight
averaging across heterogeneous pytrees, top-k sharing of Bernoulli
probabilities).  See docs/API.md for the full protocol and migration
table from the legacy trainers.
"""
from repro.core.api import Federation, History, RoundLog
from repro.core.populations import (HeteroClients, LMClients, Population,
                                    VisionClients, make_lm_pool)
from repro.core.strategies import (DML, DPDML, STRATEGIES, AsyncWeights,
                                   FedAvg, MedianDML, Payload, SparseDML,
                                   Strategy, TrimmedDML, get_strategy)

__all__ = [
    "Federation", "History", "RoundLog",
    "Strategy", "Payload", "STRATEGIES", "get_strategy",
    "DML", "SparseDML", "FedAvg", "AsyncWeights",
    "DPDML", "TrimmedDML", "MedianDML",
    "Population", "VisionClients", "HeteroClients", "LMClients",
    "make_lm_pool",
]
