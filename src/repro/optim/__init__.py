"""Optimizers + schedules (minimal optax-style, pure JAX).

AdamW with decoupled weight decay + global-norm clipping for the LLM path;
SGD-momentum for the VisionNet reproduction (matching the paper's small-CNN
setting).  State is a plain pytree so it checkpoints/shards like params.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

Params = Any


# ---------------------------------------------------------------------------
# schedules

def cosine_schedule(base_lr: float, warmup: int, total: int,
                    final_frac: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (final_frac + (1 - final_frac) *
                         0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def constant_schedule(base_lr: float) -> Callable:
    return lambda step: jnp.asarray(base_lr, jnp.float32)


# ---------------------------------------------------------------------------
# gradient transforms

def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


# ---------------------------------------------------------------------------
# AdamW

@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"          # or "constant"

    def make_schedule(self) -> Callable:
        if self.schedule == "cosine":
            return cosine_schedule(self.lr, self.warmup, self.total_steps)
        return constant_schedule(self.lr)


def adamw_init(params: Params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def _wd_mask(path: tuple) -> bool:
    """Decay matrices only — skip norms/biases/scalars (standard practice)."""
    names = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
    skip = ("norm", "bias", "b_qkv", "A_log", "D", "dt_bias", "conv_b", "b")
    return not any(str(n) in skip or "norm" in str(n) for n in names)


def adamw_update(params: Params, grads: Params, state: dict,
                 cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    step = state["step"] + 1
    lr = cfg.make_schedule()(step)
    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state["mu"], grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) *
                      jnp.square(g.astype(jnp.float32)), state["nu"], grads)
    t = step.astype(jnp.float32)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def upd(path, p, m, v):
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if cfg.weight_decay and _wd_mask(path):
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree_util.tree_map_with_path(upd, params, mu, nu)
    return new_params, {"mu": mu, "nu": nu, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# SGD + momentum (VisionNet path)

@dataclass(frozen=True)
class SGDConfig:
    lr: float = 0.05
    momentum: float = 0.9
    clip_norm: Optional[float] = None


def sgd_init(params: Params) -> dict:
    return {"vel": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "step": jnp.zeros((), jnp.int32)}


def sgd_update(params: Params, grads: Params, state: dict, cfg: SGDConfig):
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    vel = jax.tree.map(lambda v, g: cfg.momentum * v + g.astype(jnp.float32),
                       state["vel"], grads)
    new_params = jax.tree.map(
        lambda p, v: (p.astype(jnp.float32) - cfg.lr * v).astype(p.dtype),
        params, vel)
    return new_params, {"vel": vel, "step": state["step"] + 1}, \
        {"grad_norm": gnorm}
