"""The paper's contribution: federated learning via distributed mutual
learning (loss/prediction sharing), plus the two weight-sharing baselines.

- ``mutual``      Eq. 1/2 losses (categorical + Bernoulli)
- ``federated``   Algorithm 1 engine (VisionNet case study, 3 frameworks)
- ``distributed`` mesh-scale client-stacked steps (clients = pod axis)
- ``fedavg``      vanilla weight-averaging baseline
- ``async_fl``    asynchronous weight-updating baseline [4]
"""
from repro.core import async_fl, distributed, fedavg, federated, mutual  # noqa: F401
