"""The paper's contribution: federated learning via distributed mutual
learning (loss/prediction sharing), plus the two weight-sharing baselines,
behind one strategy-composable session layer.

- ``api``         ``Federation`` — strategy x population session engine
- ``strategies``  what crosses the wire: DML / SparseDML / FedAvg / Async
- ``populations`` who federates: stacked VisionNet / hetero registry / LM
- ``mutual``      Eq. 1/2 losses (categorical, Bernoulli, sparse top-k)
- ``federated``   legacy Algorithm-1 trainer (shim over ``Federation``)
- ``hetero``      legacy heterogeneous trainer (shim over ``Federation``)
- ``distributed`` mesh-scale client-stacked steps (clients = pod axis)
- ``fedavg``      vanilla weight-averaging baseline
- ``async_fl``    asynchronous weight-updating baseline [4]
"""
from repro.core import (api, async_fl, distributed, fedavg, federated,  # noqa: F401
                        hetero, mutual, populations, strategies)
