"""Algorithm 1 — the paper's federated loop for the VisionNet case study.

Three selectable frameworks under identical conditions (paper §III.B.3:
same architecture, same per-round data size, same epochs, IID folds):

  - 'fedavg': vanilla FL — full weight averaging every round
  - 'async' : asynchronous weight-updating FL — metric-weighted average,
              shallow every round / deep every delta-th round, plus a
              server-side global model trained on a global fold
  - 'dml'   : the proposed framework — clients share only predictions on a
              rotating public fold and descend Eq. 1
              (BCE + avg KL vs the received, fixed predictions)

Clients are a *stacked* pytree (leading axis K) and local training is
vmapped — the same client-axis layout the mesh-scale path shards over pods.
Communication bytes are accounted per round for the bandwidth claim.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.visionnet import VisionNetConfig
from repro.core import async_fl, fedavg
from repro.core.mutual import bernoulli_mutual_eval
from repro.data.federated import FoldScheduler, NonIIDScheduler
from repro.models.visionnet import (bce_loss, init_visionnet,
                                    shallow_deep_split, visionnet_forward)
from repro.optim import SGDConfig, sgd_init, sgd_update


@dataclass
class FederatedConfig:
    method: str = "dml"               # dml | fedavg | async
    n_clients: int = 5
    rounds: int = 12
    local_epochs: int = 2
    batch_size: int = 32
    lr: float = 0.05
    momentum: float = 0.9
    clip_norm: float = 1.0        # the Eq.-1 KL term spikes at sharing time
                                  # (paper Fig. 4c); clipping keeps SGD stable
    # dml
    kl_weight: float = 1.0
    mutual_epochs: int = 1
    # async
    delta: int = 3
    min_round: int = 5
    # non-IID client data (paper §VI future work): Dirichlet(alpha) class
    # skew per client; 0 -> IID stratified folds (the paper's setting)
    non_iid_alpha: float = 0.0
    seed: int = 0
    eval_batch: int = 256


@dataclass
class RoundLog:
    round: int
    client_loss: List[float]
    kl_loss: List[float]
    comm_bytes: int
    layer: Optional[str] = None


@dataclass
class History:
    rounds: List[RoundLog] = field(default_factory=list)
    client_test_acc: List[float] = field(default_factory=list)
    global_test_acc: float = 0.0
    total_comm_bytes: int = 0


# ---------------------------------------------------------------------------
# jitted steps

@functools.partial(jax.jit, static_argnames=("vn_cfg", "sgd_cfg"))
def _local_step(params, opt, images, labels, key, vn_cfg: VisionNetConfig,
                sgd_cfg: SGDConfig):
    def loss_fn(p):
        probs = visionnet_forward(p, vn_cfg, images, train=True,
                                  dropout_key=key)
        return bce_loss(probs, labels)
    loss, grads = jax.value_and_grad(loss_fn)(params)
    params, opt, _ = sgd_update(params, grads, opt, sgd_cfg)
    return params, opt, loss


@functools.partial(jax.jit, static_argnames=("vn_cfg", "sgd_cfg", "kl_weight"))
def _mutual_step(params, opt, images, labels, fixed_probs, my_idx, key,
                 vn_cfg: VisionNetConfig, sgd_cfg: SGDConfig,
                 kl_weight: float):
    """Eq. 1 step for ONE client: BCE + avg KL(live || fixed others)."""
    K = fixed_probs.shape[0]

    def loss_fn(p):
        probs = visionnet_forward(p, vn_cfg, images, train=True,
                                  dropout_key=key)
        bce = bce_loss(probs, labels)
        pl_ = jnp.clip(probs, 1e-6, 1 - 1e-6)[None, :]          # (1,B)
        pf = jnp.clip(fixed_probs, 1e-6, 1 - 1e-6)              # (K,B)
        kl = pl_ * jnp.log(pl_ / pf) + (1 - pl_) * jnp.log((1 - pl_) / (1 - pf))
        mask = (jnp.arange(K) != my_idx).astype(jnp.float32)[:, None]
        kld_avg = jnp.sum(kl * mask, axis=0) / max(K - 1, 1)    # (B,)
        return bce + kl_weight * jnp.mean(kld_avg), (bce, jnp.mean(kld_avg))
    (loss, (bce, kld)), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    params, opt, _ = sgd_update(params, grads, opt, sgd_cfg)
    return params, opt, loss, bce, kld


@functools.partial(jax.jit, static_argnames=("vn_cfg",))
def _predict(params, images, vn_cfg: VisionNetConfig):
    return visionnet_forward(params, vn_cfg, images, train=False)


# ---------------------------------------------------------------------------
# engine

class FederatedTrainer:
    """Runs Algorithm 1 on a (train_images, train_labels) pool."""

    def __init__(self, vn_cfg: VisionNetConfig, fed_cfg: FederatedConfig,
                 train_images: np.ndarray, train_labels: np.ndarray):
        self.vn_cfg = vn_cfg
        self.fed = fed_cfg
        self.images = train_images
        self.labels = train_labels
        self.sgd_cfg = SGDConfig(lr=fed_cfg.lr, momentum=fed_cfg.momentum,
                                 clip_norm=fed_cfg.clip_norm)
        self.key = jax.random.PRNGKey(fed_cfg.seed)
        # Algorithm 1 line 1: Fold <- (1+Clients) x Rounds + 1
        if fed_cfg.non_iid_alpha > 0:
            self.folds = NonIIDScheduler(train_labels, fed_cfg.n_clients,
                                         fed_cfg.rounds,
                                         alpha=fed_cfg.non_iid_alpha,
                                         seed=fed_cfg.seed)
        else:
            self.folds = FoldScheduler(train_labels, fed_cfg.n_clients,
                                       fed_cfg.rounds, seed=fed_cfg.seed)
        # line 3/6: global model trained on public fold
        self.key, kg = jax.random.split(self.key)
        self.global_params = init_visionnet(kg, vn_cfg)
        self.global_opt = sgd_init(self.global_params)
        self._train_single("global", self.folds.pop())
        # lines 7-8: clients start from G
        K = fed_cfg.n_clients
        self.client_params = jax.tree.map(
            lambda p: jnp.broadcast_to(p[None], (K,) + p.shape).copy(),
            self.global_params)
        self.client_opts = {
            "vel": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                self.client_params),
            "step": jnp.zeros((K,), jnp.int32)}
        self.n_params = sum(p.size for p in jax.tree.leaves(self.global_params))
        self.shallow_mask = shallow_deep_split(self.global_params)
        self.history = History()

    # -- helpers ----------------------------------------------------------
    def _batches(self, fold: np.ndarray, epochs: int):
        bs = self.fed.batch_size
        rng = np.random.default_rng(int(fold[0]) + 17)
        for _ in range(epochs):
            order = rng.permutation(len(fold))
            for i in range(0, len(order) - bs + 1, bs):
                idx = fold[order[i: i + bs]]
                yield self.images[idx], self.labels[idx]

    def _train_single(self, which: str, fold: np.ndarray):
        losses = []
        for imgs, labs in self._batches(fold, self.fed.local_epochs):
            self.key, k = jax.random.split(self.key)
            self.global_params, self.global_opt, loss = _local_step(
                self.global_params, self.global_opt, jnp.asarray(imgs),
                jnp.asarray(labs), k, self.vn_cfg, self.sgd_cfg)
            losses.append(float(loss))
        return float(np.mean(losses)) if losses else 0.0

    def _train_client(self, c: int, fold: np.ndarray) -> float:
        """Local training of client c (stacked storage, per-client slices)."""
        params = jax.tree.map(lambda p: p[c], self.client_params)
        opt = {"vel": jax.tree.map(lambda p: p[c], self.client_opts["vel"]),
               "step": self.client_opts["step"][c]}
        losses = []
        for imgs, labs in self._batches(fold, self.fed.local_epochs):
            self.key, k = jax.random.split(self.key)
            params, opt, loss = _local_step(params, opt, jnp.asarray(imgs),
                                            jnp.asarray(labs), k,
                                            self.vn_cfg, self.sgd_cfg)
            losses.append(float(loss))
        self.client_params = jax.tree.map(
            lambda s, p: s.at[c].set(p), self.client_params, params)
        self.client_opts["vel"] = jax.tree.map(
            lambda s, p: s.at[c].set(p), self.client_opts["vel"], opt["vel"])
        self.client_opts["step"] = self.client_opts["step"].at[c].set(opt["step"])
        return float(np.mean(losses)) if losses else 0.0

    def _client_accuracy(self, c: int, images, labels) -> float:
        params = jax.tree.map(lambda p: p[c], self.client_params)
        correct = 0
        for i in range(0, len(images), self.fed.eval_batch):
            probs = _predict(params, jnp.asarray(images[i:i + self.fed.eval_batch]),
                             self.vn_cfg)
            correct += int(np.sum((np.asarray(probs) > 0.5) ==
                                  labels[i:i + self.fed.eval_batch]))
        return correct / len(images)

    def _accuracy_on(self, params, images, labels) -> float:
        correct = 0
        for i in range(0, len(images), self.fed.eval_batch):
            probs = _predict(params, jnp.asarray(images[i:i + self.fed.eval_batch]),
                             self.vn_cfg)
            correct += int(np.sum((np.asarray(probs) > 0.5) ==
                                  labels[i:i + self.fed.eval_batch]))
        return correct / len(images)

    # -- rounds -----------------------------------------------------------
    def run(self) -> History:
        for r in range(self.fed.rounds):
            if self.fed.method == "dml":
                self._round_dml(r)
            elif self.fed.method == "fedavg":
                self._round_fedavg(r)
            elif self.fed.method == "async":
                self._round_async(r)
            else:
                raise ValueError(self.fed.method)
        return self.history

    def _round_dml(self, r: int):
        K = self.fed.n_clients
        local_losses = [self._train_client(c, self.folds.pop())
                        for c in range(K)]
        # public fold: rotating common test set from the server
        pub = self.folds.pop()
        pub_imgs = jnp.asarray(self.images[pub])
        pub_labs = jnp.asarray(self.labels[pub])
        kl_losses = [0.0] * K
        for _ in range(self.fed.mutual_epochs):
            # inference + sharing: each client ships (B_pub,) probabilities
            all_probs = jnp.stack([
                _predict(jax.tree.map(lambda p: p[c], self.client_params),
                         pub_imgs, self.vn_cfg) for c in range(K)])
            comm = 2 * K * all_probs.shape[1] * 4        # up + broadcast down
            for c in range(K):
                params = jax.tree.map(lambda p: p[c], self.client_params)
                opt = {"vel": jax.tree.map(lambda p: p[c], self.client_opts["vel"]),
                       "step": self.client_opts["step"][c]}
                self.key, k = jax.random.split(self.key)
                params, opt, loss, bce, kld = _mutual_step(
                    params, opt, pub_imgs, pub_labs, all_probs,
                    jnp.int32(c), k, self.vn_cfg, self.sgd_cfg,
                    self.fed.kl_weight)
                kl_losses[c] = float(kld)
                local_losses[c] = float(loss)
                self.client_params = jax.tree.map(
                    lambda s, p: s.at[c].set(p), self.client_params, params)
                self.client_opts["vel"] = jax.tree.map(
                    lambda s, p: s.at[c].set(p), self.client_opts["vel"],
                    opt["vel"])
                self.client_opts["step"] = \
                    self.client_opts["step"].at[c].set(opt["step"])
        self.history.total_comm_bytes += comm
        self.history.rounds.append(RoundLog(r, local_losses, kl_losses, comm))

    def _round_fedavg(self, r: int):
        K = self.fed.n_clients
        losses = [self._train_client(c, self.folds.pop()) for c in range(K)]
        self.folds.pop()                                  # global fold unused
        self.client_params = fedavg.average_weights(self.client_params)
        self.global_params = jax.tree.map(lambda p: p[0], self.client_params)
        comm = fedavg.comm_bytes_per_round(self.n_params, K)
        self.history.total_comm_bytes += comm
        self.history.rounds.append(RoundLog(r, losses, [0.0] * K, comm))

    def _round_async(self, r: int):
        K = self.fed.n_clients
        losses, scores = [], []
        for c in range(K):
            fold = self.folds.pop()
            losses.append(self._train_client(c, fold))
            scores.append(self._client_accuracy(c, self.images[fold],
                                                self.labels[fold]))
        stacked_mask = jax.tree.map(
            lambda m: m, self.shallow_mask)               # same mask all clients
        self.client_params, layer = async_fl.async_round_update(
            self.client_params, jnp.asarray(scores), stacked_mask, r,
            self.fed.delta, self.fed.min_round)
        # Algorithm 1 lines 17-18: G takes the average then trains on a fold
        self.global_params = jax.tree.map(lambda p: p[0], self.client_params)
        gl = self._train_single("global", self.folds.pop())
        n_sh, n_dp = async_fl.count_params_by_mask(self.global_params,
                                                   self.shallow_mask)
        comm = async_fl.comm_bytes_per_round(n_sh, n_dp, K, layer)
        self.history.total_comm_bytes += comm
        self.history.rounds.append(RoundLog(r, losses, [0.0] * K, comm,
                                            layer=layer))

    # -- final eval (paper Table II / Fig. 3) ------------------------------
    def evaluate(self, test_images: np.ndarray, test_labels: np.ndarray):
        K = self.fed.n_clients
        self.history.client_test_acc = [
            self._client_accuracy(c, test_images, test_labels)
            for c in range(K)]
        self.history.global_test_acc = self._accuracy_on(
            self.global_params, test_images, test_labels)
        return self.history
