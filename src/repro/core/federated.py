"""Algorithm 1 — the paper's federated loop for the VisionNet case study.

Three selectable frameworks under identical conditions (paper §III.B.3:
same architecture, same per-round data size, same epochs, IID folds):

  - 'fedavg': vanilla FL — full weight averaging every round
  - 'async' : asynchronous weight-updating FL — metric-weighted average,
              shallow every round / deep every delta-th round, plus a
              server-side global model trained on a global fold
  - 'dml'   : the proposed framework — clients share only predictions on a
              rotating public fold and descend Eq. 1
              (BCE + avg KL vs the received, fixed predictions)

Clients are a *stacked* pytree (leading axis K — ``core.stacking``, the
same client-axis layout the mesh-scale path shards over pods) and a full
round executes as a handful of jitted programs instead of O(K · batches)
Python-dispatched calls:

  _local_scan     vmap over clients of lax.scan over the fixed-shape
                  (K, T, B) batch plan from ``data.federated``
  _mutual_scan    all mutual epochs fused: dropout-free share + Eq.-1
                  descent for all K clients (``mutual.bernoulli_mutual_terms_vs``)
  _predict_stacked  vmapped inference — sharing, scores, and eval

With a ``clients`` mesh (``FederatedTrainer(..., mesh=...)``) the same two
training programs run inside ``sharding.shard_map`` over the client axis:
each device owns whole clients (round-robin spill for K > n_devices via
``stacking.client_layout``), local training is collective-free, and the
mutual phase's ONLY cross-device traffic is one all-gather of the public-
fold predictions per mutual epoch — exactly the bytes
``comm_bytes_per_round`` simulates.  Results are bitwise-identical to the
unsharded engine (tests/test_multidevice.py holds this for all 3 methods).

Communication bytes are accounted per round for the bandwidth claim.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import checkpoint, sharding
from repro.configs.visionnet import VisionNetConfig
from repro.core import async_fl, fedavg, stacking
from repro.core.mutual import _pair_mask, bernoulli_mutual_terms_vs
from repro.data.federated import (FoldScheduler, NonIIDScheduler,
                                  round_batch_indices, sample_participants)
from repro.models.visionnet import (bce_loss, init_visionnet,
                                    shallow_deep_split, visionnet_forward)
from repro.optim import SGDConfig, sgd_init, sgd_update


@dataclass
class FederatedConfig:
    method: str = "dml"               # dml | fedavg | async
    n_clients: int = 5
    rounds: int = 12
    local_epochs: int = 2
    batch_size: int = 32
    lr: float = 0.05
    momentum: float = 0.9
    clip_norm: float = 1.0        # the Eq.-1 KL term spikes at sharing time
                                  # (paper Fig. 4c); clipping keeps SGD stable
    # dml
    kl_weight: float = 1.0
    mutual_epochs: int = 1
    # async
    delta: int = 3
    min_round: int = 5
    # partial participation: sample M <= K clients per round (0 -> all K);
    # non-participants are excluded from the Eq.-2 average via masking and
    # keep their params/opt untouched; comm costs scale with M
    participation: int = 0
    # non-IID client data (paper §VI future work): Dirichlet(alpha) class
    # skew per client; 0 -> IID stratified folds (the paper's setting)
    non_iid_alpha: float = 0.0
    seed: int = 0
    eval_batch: int = 256


@dataclass
class RoundLog:
    round: int
    client_loss: List[float]
    kl_loss: List[float]
    comm_bytes: int
    layer: Optional[str] = None
    participants: Optional[List[int]] = None      # None -> full participation


@dataclass
class History:
    rounds: List[RoundLog] = field(default_factory=list)
    client_test_acc: List[float] = field(default_factory=list)
    global_test_acc: float = 0.0
    total_comm_bytes: int = 0


# ---------------------------------------------------------------------------
# jitted programs — each one covers ALL K clients in a single dispatch


def _masked_lerp(old, new, w):
    """Apply ``new`` only where the step is real (w=1); padding keeps old."""
    return jax.tree.map(lambda a, b: w * b + (1 - w) * a, old, new)


def _local_scan_impl(stacked_params, stacked_opt, images, labels, masks,
                     keys, vn_cfg: VisionNetConfig, sgd_cfg: SGDConfig,
                     conv_impl: str = "fused"):
    """Body of ``_local_scan`` — also the per-device shard_map body of
    ``_sharded_local_scan`` (per-client work is embarrassingly parallel, so
    the sharded engine runs this code unchanged on each device's slice).

    K > 1 runs in canonical width-2 client chunks
    (``stacking.chunked_client_map``) so the per-client arithmetic is
    bit-identical no matter how many clients this program instance holds;
    K == 1 (the global model) keeps the plain single-client vmap.
    """

    def one_client(params, opt, imgs, labs, w, ks):
        def body(carry, xs):
            p, o = carry
            im, la, wi, k = xs

            def loss_fn(q):
                probs = visionnet_forward(q, vn_cfg, im, train=True,
                                          dropout_key=k,
                                          conv_impl=conv_impl)
                return bce_loss(probs, la)

            loss, grads = jax.value_and_grad(loss_fn)(p)
            p2, o2, _ = sgd_update(p, grads, o, sgd_cfg)
            p2 = _masked_lerp(p, p2, wi)
            o2 = {"vel": _masked_lerp(o["vel"], o2["vel"], wi),
                  "step": o["step"] + wi.astype(jnp.int32)}
            return (p2, o2), loss * wi

        (params, opt), losses = jax.lax.scan(body, (params, opt),
                                             (imgs, labs, w, ks))
        return params, opt, jnp.sum(losses) / jnp.maximum(jnp.sum(w), 1.0)

    args = (stacked_params, stacked_opt, images, labels, masks, keys)
    K = jax.tree.leaves(stacked_params)[0].shape[0]
    if K == 1:
        return jax.vmap(one_client)(*args)
    return stacking.chunked_client_map(
        lambda a, _c: jax.vmap(one_client)(*a), args, K)


@functools.partial(jax.jit, static_argnames=("vn_cfg", "sgd_cfg",
                                             "conv_impl"))
def _local_scan(stacked_params, stacked_opt, images, labels, masks, keys,
                vn_cfg: VisionNetConfig, sgd_cfg: SGDConfig,
                conv_impl: str = "fused"):
    """Local epochs for all clients: vmap(client) of scan(batch plan).

    images (K,T,B,H,W,C) · labels (K,T,B) · masks (K,T) · keys (K,T,2).
    Returns (stacked_params, stacked_opt, mean BCE per client (K,)).
    """
    return _local_scan_impl(stacked_params, stacked_opt, images, labels,
                            masks, keys, vn_cfg, sgd_cfg, conv_impl)


@functools.lru_cache(maxsize=None)
def _sharded_local_program(mesh, n_clients: int, vn_cfg: VisionNetConfig,
                           sgd_cfg: SGDConfig, conv_impl: str):
    body = functools.partial(_local_scan_impl, vn_cfg=vn_cfg,
                             sgd_cfg=sgd_cfg, conv_impl=conv_impl)
    spec = stacking.client_spec()
    return jax.jit(sharding.shard_map(body, mesh, in_specs=(spec,) * 6,
                                      out_specs=(spec, spec, spec)))


def _sharded_local_scan(stacked_params, stacked_opt, images, labels, masks,
                        keys, mesh, n_clients: int,
                        vn_cfg: VisionNetConfig, sgd_cfg: SGDConfig,
                        conv_impl: str = "fused"):
    """``_local_scan`` inside shard_map over the ``clients`` mesh axis.

    Each device trains only the clients it owns (round-robin layout from
    ``stacking``; K > n_devices spills extra clients as second/third slots)
    and the phase runs with ZERO cross-device collectives — private data
    never leaves its device, matching the paper's locality claim.

    The round-robin reorder/pad runs EAGERLY, outside the jitted shard_map
    program: an in-jit gather feeding shard_map lets XLA's layout
    assignment propagate non-standard layouts into the per-device body,
    whose convs/GEMMs then round differently from the unsharded engine.
    """
    n_dev = mesh.shape[stacking.CLIENT_AXIS]
    shard = lambda t: stacking.shard_clients(t, n_clients, n_dev)
    run = _sharded_local_program(mesh, n_clients, vn_cfg, sgd_cfg,
                                 conv_impl)
    p, o, losses = run(shard(stacked_params), shard(stacked_opt),
                       shard(images), shard(labels), shard(masks),
                       shard(keys))
    unshard = lambda t: stacking.unshard_clients(t, n_clients, n_dev)
    return unshard(p), unshard(o), unshard(losses)


def _isolated_epoch(epoch):
    """Pin a scan body as its own compilation unit.  XLA inlines
    trip-count-1 loops (mutual_epochs=1 is the default), and an inlined
    epoch fuses with its surroundings — which differ between the sharded
    and unsharded engines — breaking their bitwise parity."""
    def wrapped(carry, xs):
        carry, xs = jax.lax.optimization_barrier((carry, xs))
        return jax.lax.optimization_barrier(epoch(carry, xs))
    return wrapped


def _predict_chunked(stacked_params, images, vn_cfg: VisionNetConfig):
    """Dropout-free stacked forward in canonical client chunks: (K, B)."""
    K = jax.tree.leaves(stacked_params)[0].shape[0]
    fn = lambda a, c: jax.vmap(
        lambda q: visionnet_forward(q, vn_cfg, c[0], train=False))(a[0])
    return stacking.chunked_client_map(fn, (stacked_params,), K,
                                       const_args=(images,))


def _mutual_epoch_step(stacked_params, stacked_opt, keys_e, pm_rows,
                       pair_rows, shared, pub_images, pub_labels,
                       vn_cfg: VisionNetConfig, sgd_cfg: SGDConfig,
                       kl_weight: float, conv_impl: str):
    """One Eq.-1 descent for a stack of clients against FIXED shared
    predictions.

    ``shared`` (K, B) is the fleet's dropout-free public-fold predictions
    in natural client order (already stop-gradient'ed: received predictions
    are data); ``pair_rows`` the matching rows of the Eq.-2 pair mask, and
    ``pm_rows`` the rows' participation bits.  Runs in canonical width-2
    chunks, so the unsharded engine (full K rows) and each device of the
    sharded engine (its K_loc rows) execute bit-identical per-client
    arithmetic.  Returns (params, opt, (bce, kld)).
    """

    def chunk(args, const):
        c_params, c_opt, c_keys, c_pm, c_w = args
        c_shared, c_imgs, c_labs = const

        def total_loss(cp):
            live = jax.vmap(
                lambda q, k: visionnet_forward(q, vn_cfg, c_imgs,
                                               train=True, dropout_key=k,
                                               conv_impl=conv_impl)
            )(cp, c_keys)                                       # (2,B)
            bce = jax.vmap(lambda pr: bce_loss(pr, c_labs))(live)
            kld = jnp.mean(bernoulli_mutual_terms_vs(live, c_shared, c_w),
                           axis=-1)                             # (2,)
            return (jnp.sum(bce * c_pm) + kl_weight * jnp.sum(kld),
                    (bce, kld))

        (_, (bce, kld)), grads = jax.value_and_grad(
            total_loss, has_aux=True)(c_params)
        # per-client update so grad clipping stays per client, exactly as
        # in the per-client loop this replaces
        new_p, new_o, _ = jax.vmap(
            lambda q, g, o: sgd_update(q, g, o, sgd_cfg))(c_params, grads,
                                                          c_opt)
        p = jax.vmap(_masked_lerp)(c_params, new_p, c_pm)
        o = {"vel": jax.vmap(_masked_lerp)(c_opt["vel"], new_o["vel"],
                                           c_pm),
             "step": c_opt["step"] + c_pm.astype(jnp.int32)}
        return p, o, (bce, kld)

    K = jax.tree.leaves(stacked_params)[0].shape[0]
    return stacking.chunked_client_map(
        chunk, (stacked_params, stacked_opt, keys_e, pm_rows, pair_rows), K,
        const_args=(shared, pub_images, pub_labels))


@functools.partial(jax.jit, static_argnames=("vn_cfg", "sgd_cfg",
                                             "kl_weight", "conv_impl"))
def _mutual_scan(stacked_params, stacked_opt, pub_images, pub_labels, keys,
                 part_mask, vn_cfg: VisionNetConfig, sgd_cfg: SGDConfig,
                 kl_weight: float, conv_impl: str = "fused"):
    """All mutual epochs for all K clients, fused into one program.

    keys (E, K, 2) · part_mask (K,) 0/1.  Per epoch: every participant
    shares its dropout-free predictions on the public fold (what actually
    goes over the wire), then descends Eq. 1 — BCE + kl_weight · KLD vs the
    received tensor held fixed.  Partial participation masks absentees out
    of the Eq.-2 average AND out of the update (their params/opt ride
    through unchanged).  Returns the final epoch's per-client
    (total loss, bce, kld), each (K,).
    """
    K = jax.tree.leaves(stacked_params)[0].shape[0]
    pair_w = _pair_mask(K, part_mask)

    def epoch(carry, ks):
        params, opt = carry
        shared = jax.lax.stop_gradient(
            _predict_chunked(params, pub_images, vn_cfg))          # (K,B)
        params, opt, (bce, kld) = _mutual_epoch_step(
            params, opt, ks, part_mask, pair_w, shared, pub_images,
            pub_labels, vn_cfg, sgd_cfg, kl_weight, conv_impl)
        return (params, opt), (bce + kl_weight * kld, bce, kld)

    (stacked_params, stacked_opt), (loss, bce, kld) = jax.lax.scan(
        _isolated_epoch(epoch), (stacked_params, stacked_opt), keys)
    return stacked_params, stacked_opt, (loss[-1], bce[-1], kld[-1])


@functools.lru_cache(maxsize=None)
def _sharded_mutual_program(mesh, n_clients: int, vn_cfg: VisionNetConfig,
                            sgd_cfg: SGDConfig, kl_weight: float,
                            conv_impl: str):
    n_dev = mesh.shape[stacking.CLIENT_AXIS]

    def body(params, opt, pub_imgs, pub_labs, ks, pm_full):
        gids = stacking.local_client_ids(n_clients, n_dev)
        safe = jnp.minimum(gids, n_clients - 1)
        real = (gids < n_clients).astype(jnp.float32)    # 0 on dummy slots
        pm_loc = jnp.take(pm_full, safe) * real
        pair_rows = jnp.take(_pair_mask(n_clients, pm_full), safe,
                             axis=0) * real[:, None]

        def epoch(carry, kk):
            params, opt = carry
            shared_loc = _predict_chunked(params, pub_imgs,
                                          vn_cfg)        # (K_loc, B)
            shared = jax.lax.stop_gradient(stacking.gather_clients(
                shared_loc, n_clients, n_dev)[:n_clients])  # (K, B) natural
            params, opt, (bce, kld) = _mutual_epoch_step(
                params, opt, kk, pm_loc, pair_rows, shared, pub_imgs,
                pub_labs, vn_cfg, sgd_cfg, kl_weight, conv_impl)
            return (params, opt), (bce + kl_weight * kld, bce, kld)

        (params, opt), (loss, bce, kld) = jax.lax.scan(
            _isolated_epoch(epoch), (params, opt), ks)
        return params, opt, (loss[-1], bce[-1], kld[-1])

    spec = stacking.client_spec()
    return jax.jit(sharding.shard_map(
        body, mesh,
        in_specs=(spec, spec, P(), P(), P(None, stacking.CLIENT_AXIS), P()),
        out_specs=(spec, spec, (spec, spec, spec))))


def _sharded_mutual_scan(stacked_params, stacked_opt, pub_images, pub_labels,
                         keys, part_mask, mesh, n_clients: int,
                         vn_cfg: VisionNetConfig, sgd_cfg: SGDConfig,
                         kl_weight: float, conv_impl: str = "fused"):
    """``_mutual_scan`` inside shard_map over the ``clients`` mesh axis.

    Per mutual epoch each device forwards its own clients on the public
    fold and the (K_loc, B_pub) predictions are all-gathered — the ONLY
    cross-device collective of the whole round, and precisely the tensor
    Algorithm 1 says crosses client boundaries.  The gathered fleet is
    restored to natural client order (``stacking.gather_clients``) before
    the Eq.-2 sum so reduction order — and hence every float — matches the
    unsharded engine bitwise.  Each device then descends Eq. 1 for its own
    clients only (rows of the pair-mask select them); dummies from the
    round-robin padding are masked out of both the average and the update.
    The reorder/pad runs eagerly outside the jitted program (see
    ``_sharded_local_scan`` — in-jit gathers perturb body layouts).
    """
    n_dev = mesh.shape[stacking.CLIENT_AXIS]
    run = _sharded_mutual_program(mesh, n_clients, vn_cfg, sgd_cfg,
                                  kl_weight, conv_impl)
    p, o, (loss, bce, kld) = run(
        stacking.shard_clients(stacked_params, n_clients, n_dev),
        stacking.shard_clients(stacked_opt, n_clients, n_dev),
        pub_images, pub_labels,
        stacking.shard_clients(keys, n_clients, n_dev, axis=1),
        jnp.asarray(part_mask, jnp.float32))
    unshard = lambda t: stacking.unshard_clients(t, n_clients, n_dev)
    return unshard(p), unshard(o), (unshard(loss), unshard(bce),
                                    unshard(kld))


@functools.partial(jax.jit, static_argnames=("vn_cfg",))
def _predict_stacked(stacked_params, images, vn_cfg: VisionNetConfig):
    """Vmapped inference on a SHARED batch: (K-stacked params, (B,...)) ->
    (K, B) probabilities.  The sharing / eval / accuracy path."""
    return jax.vmap(lambda p: visionnet_forward(p, vn_cfg, images,
                                                train=False))(stacked_params)


@functools.partial(jax.jit, static_argnames=("vn_cfg",))
def _accuracy_scan(stacked_params, images, labels, masks,
                   vn_cfg: VisionNetConfig):
    """Per-client accuracy on per-client (padded) data:
    images (K,N,H,W,C) · labels (K,N) · masks (K,N) -> (K,)."""
    probs = jax.vmap(
        lambda p, im: visionnet_forward(p, vn_cfg, im, train=False)
    )(stacked_params, images)
    hit = ((probs > 0.5) == (labels > 0.5)).astype(jnp.float32)
    return jnp.sum(hit * masks, axis=1) / jnp.maximum(
        jnp.sum(masks, axis=1), 1.0)


# ---------------------------------------------------------------------------
# engine

class FederatedTrainer:
    """Runs Algorithm 1 on a (train_images, train_labels) pool.

    ``mesh``: optional jax Mesh with a ``clients`` axis — the round's two
    training programs then run device-sharded over the client axis
    (bitwise-identical results; see the sharded program docstrings).
    """

    def __init__(self, vn_cfg: VisionNetConfig, fed_cfg: FederatedConfig,
                 train_images: np.ndarray, train_labels: np.ndarray,
                 mesh=None):
        if mesh is not None and stacking.CLIENT_AXIS not in mesh.axis_names:
            raise ValueError(
                f"mesh needs a '{stacking.CLIENT_AXIS}' axis, got "
                f"{mesh.axis_names}")
        self.mesh = mesh
        self.vn_cfg = vn_cfg
        self.fed = fed_cfg
        self.images = train_images
        self.labels = train_labels
        self.sgd_cfg = SGDConfig(lr=fed_cfg.lr, momentum=fed_cfg.momentum,
                                 clip_norm=fed_cfg.clip_norm)
        self.key = jax.random.PRNGKey(fed_cfg.seed)
        self._plan_seed = fed_cfg.seed * 100_003 + 17
        # (round, program) pairs — one entry per jitted dispatch, so tests
        # can assert the engine really is a handful of programs per round
        self.dispatch_log: List[Tuple[int, str]] = []
        self._round_idx = -1                      # -1 = init phase
        # Algorithm 1 line 1: Fold <- (1+Clients) x Rounds + 1
        if fed_cfg.non_iid_alpha > 0:
            self.folds = NonIIDScheduler(train_labels, fed_cfg.n_clients,
                                         fed_cfg.rounds,
                                         alpha=fed_cfg.non_iid_alpha,
                                         seed=fed_cfg.seed)
        else:
            self.folds = FoldScheduler(train_labels, fed_cfg.n_clients,
                                       fed_cfg.rounds, seed=fed_cfg.seed)
        # line 3/6: global model trained on public fold
        self.key, kg = jax.random.split(self.key)
        self.global_params = init_visionnet(kg, vn_cfg)
        self.global_opt = sgd_init(self.global_params)
        self._train_single(self.folds.pop())
        # lines 7-8: clients start from G
        K = fed_cfg.n_clients
        self.client_params = stacking.broadcast_stack(self.global_params, K)
        self.client_opts = stacking.stacked_sgd_init(self.client_params)
        self.n_params = sum(p.size for p in jax.tree.leaves(self.global_params))
        self.shallow_mask = shallow_deep_split(self.global_params)
        self.history = History()
        self._next_round = 0

    # -- helpers ----------------------------------------------------------
    def participants(self, r: int) -> List[int]:
        """The M clients sampled for round r (stateless in r — resume-safe).
        Full participation returns all K."""
        return sample_participants(self.fed.n_clients, self.fed.participation,
                                   self.fed.seed, r)

    def _part_mask(self, part: List[int]) -> np.ndarray:
        mask = np.zeros((self.fed.n_clients,), np.float32)
        mask[part] = 1.0
        return mask

    def _next_plan_seed(self) -> int:
        self._plan_seed += 1
        return self._plan_seed

    def _split_keys(self, *shape) -> jax.Array:
        """Dropout keys for a whole program at once: (*shape, 2) uint32."""
        self.key, sub = jax.random.split(self.key)
        n = int(np.prod(shape))
        return jax.random.split(sub, n).reshape(*shape, 2)

    def _gather(self, idx: np.ndarray):
        return jnp.asarray(self.images[idx]), jnp.asarray(self.labels[idx])

    def _train_single(self, fold: np.ndarray) -> float:
        """Global-model training = the SAME scan program with K=1."""
        idx, mask = round_batch_indices([fold], self.fed.local_epochs,
                                        self.fed.batch_size,
                                        seed=self._next_plan_seed())
        if idx.shape[1] == 0:
            return 0.0
        imgs, labs = self._gather(idx)
        keys = self._split_keys(1, idx.shape[1])
        gp = stacking.expand_stack(self.global_params)
        go = stacking.expand_stack(self.global_opt)
        gp, go, losses = _local_scan(gp, go, imgs, labs, jnp.asarray(mask),
                                     keys, self.vn_cfg, self.sgd_cfg,
                                     conv_impl="native")
        self.dispatch_log.append((self._round_idx, "local_scan"))
        self.global_params = stacking.client_slice(gp, 0)
        self.global_opt = stacking.client_slice(go, 0)
        return float(losses[0])

    def _local_round(self, part_mask: Optional[np.ndarray] = None):
        """Pop K client folds and run every client's local epochs in ONE
        vmapped scan dispatch.  Returns (folds, per-client mean loss).

        ``part_mask`` (K,) 0/1 zeroes the whole batch plan of absent
        clients — their params/opt ride through the scan untouched (the
        masked-lerp padding path), exactly as if they never trained.
        """
        K = self.fed.n_clients
        folds, idx, mask = self.folds.pop_round(
            K, self.fed.local_epochs, self.fed.batch_size,
            seed=self._next_plan_seed())
        if idx.shape[1] == 0:
            return folds, [0.0] * K
        if part_mask is not None:
            mask = mask * part_mask[:, None]
        imgs, labs = self._gather(idx)
        keys = self._split_keys(K, idx.shape[1])
        if self.mesh is not None and K > 1:
            self._to_mesh()
            self.client_params, self.client_opts, losses = \
                _sharded_local_scan(self.client_params, self.client_opts,
                                    imgs, labs, jnp.asarray(mask), keys,
                                    self.mesh, K, self.vn_cfg, self.sgd_cfg,
                                    conv_impl="fused")
        else:
            self.client_params, self.client_opts, losses = _local_scan(
                self.client_params, self.client_opts, imgs, labs,
                jnp.asarray(mask), keys, self.vn_cfg, self.sgd_cfg,
                conv_impl="fused" if K > 1 else "native")
        self.dispatch_log.append((self._round_idx, "local_scan"))
        return folds, [float(x) for x in np.asarray(losses)]

    def _gather_clients_host(self):
        """Commit the (possibly client-sharded) client state to one device.
        The weight-sharing baselines gather every client's weights by
        definition; doing it explicitly keeps their sync math — reduction
        order included — bitwise-identical to the unsharded engine."""
        if self.mesh is None:
            return
        dev = jax.devices()[0]
        self.client_params = jax.device_put(self.client_params, dev)
        self.client_opts = jax.device_put(self.client_opts, dev)

    def _to_mesh(self):
        """Re-place single-device-committed client state onto the mesh
        (after a weight-sharing sync gathered it) so the sharded programs
        see consistent devices; DML chains keep their sharded placement."""
        leaf = jax.tree.leaves(self.client_params)[0]
        if not isinstance(getattr(leaf, "sharding", None),
                          jax.sharding.SingleDeviceSharding):
            return
        sh = jax.sharding.NamedSharding(self.mesh, P())
        self.client_params = jax.device_put(self.client_params, sh)
        self.client_opts = jax.device_put(self.client_opts, sh)

    def _fold_accuracies(self, folds) -> List[float]:
        """Each client scored on its OWN fold — one vmapped dispatch over a
        padded (K, N) stack (the async baseline's weighting metric)."""
        n = max(max((len(f) for f in folds), default=0), 1)
        K = len(folds)
        idx = np.zeros((K, n), np.int64)
        mask = np.zeros((K, n), np.float32)
        for c, f in enumerate(folds):
            idx[c, :len(f)] = f
            mask[c, :len(f)] = 1.0
        imgs, labs = self._gather(idx)
        acc = _accuracy_scan(self.client_params, imgs, labs,
                             jnp.asarray(mask), self.vn_cfg)
        self.dispatch_log.append((self._round_idx, "accuracy_scan"))
        return [float(a) for a in np.asarray(acc)]

    def _accuracy_chunked(self, stacked_params, images, labels) -> np.ndarray:
        """All clients' accuracy on a SHARED dataset via the vmapped
        predict, eval_batch examples at a time.  Returns (K,)."""
        K = jax.tree.leaves(stacked_params)[0].shape[0]
        correct = np.zeros((K,), np.int64)
        for i in range(0, len(images), self.fed.eval_batch):
            probs = _predict_stacked(stacked_params,
                                     jnp.asarray(images[i:i + self.fed.eval_batch]),
                                     self.vn_cfg)
            self.dispatch_log.append((self._round_idx, "predict"))
            correct += np.sum((np.asarray(probs) > 0.5) ==
                              labels[None, i:i + self.fed.eval_batch], axis=1)
        return correct / len(images)

    # -- rounds -----------------------------------------------------------
    def run(self, until: int = 0) -> History:
        """Run rounds up to ``until`` (0 -> cfg.rounds).  Picks up from the
        round counter, so save_state/restore_state mid-run and a second
        ``run()`` continue exactly where the checkpoint left off."""
        stop = until or self.fed.rounds
        for r in range(self._next_round, min(stop, self.fed.rounds)):
            self._round_idx = r
            part = self.participants(r)
            if self.fed.method == "dml":
                self._round_dml(r, part)
            elif self.fed.method == "fedavg":
                self._round_fedavg(r, part)
            elif self.fed.method == "async":
                self._round_async(r, part)
            else:
                raise ValueError(self.fed.method)
            self._next_round = r + 1
        return self.history

    def _log_round(self, r, part, losses, kls, comm, layer=None):
        full = len(part) == self.fed.n_clients
        self.history.total_comm_bytes += comm
        self.history.rounds.append(RoundLog(
            r, losses, kls, comm, layer=layer,
            participants=None if full else part))

    def _round_dml(self, r: int, part: List[int]):
        K = self.fed.n_clients
        pm = self._part_mask(part)
        _, local_losses = self._local_round(pm if len(part) < K else None)
        # public fold: rotating common test set from the server
        pub = self.folds.pop()
        kl_losses = [0.0] * K
        comm = 0
        if self.fed.mutual_epochs > 0 and len(part) >= 2:
            pub_imgs = jnp.asarray(self.images[pub])
            pub_labs = jnp.asarray(self.labels[pub])
            keys = self._split_keys(self.fed.mutual_epochs, K)
            if self.mesh is not None and K > 1:
                self.client_params, self.client_opts, (loss, _, kld) = \
                    _sharded_mutual_scan(self.client_params,
                                         self.client_opts, pub_imgs,
                                         pub_labs, keys, jnp.asarray(pm),
                                         self.mesh, K, self.vn_cfg,
                                         self.sgd_cfg, self.fed.kl_weight,
                                         conv_impl="fused")
            else:
                self.client_params, self.client_opts, (loss, _, kld) = \
                    _mutual_scan(self.client_params, self.client_opts,
                                 pub_imgs, pub_labs, keys, jnp.asarray(pm),
                                 self.vn_cfg, self.sgd_cfg,
                                 self.fed.kl_weight,
                                 conv_impl="fused" if K > 1 else "native")
            self.dispatch_log.append((r, "mutual_scan"))
            local_losses = [float(x) * m for x, m in
                            zip(np.asarray(loss), pm)]
            kl_losses = [float(x) for x in np.asarray(kld)]
            # inference + sharing: each PARTICIPANT ships (B_pub,)
            # probabilities up and receives the (M, B_pub) broadcast down,
            # EVERY epoch — bytes scale with M, not K
            comm = self.fed.mutual_epochs * 2 * len(part) * len(pub) * 4
        self._log_round(r, part, local_losses, kl_losses, comm)

    def _round_fedavg(self, r: int, part: List[int]):
        K = self.fed.n_clients
        pm = self._part_mask(part)
        _, losses = self._local_round(pm if len(part) < K else None)
        self._gather_clients_host()
        self.folds.pop()                                  # global fold unused
        if len(part) == K:
            self.client_params = fedavg.average_weights(self.client_params)
            avg = self.client_params
        else:
            # server averages the M participants; only they receive the
            # broadcast back (absentees are offline this round)
            avg = fedavg.weighted_average_weights(self.client_params,
                                                  jnp.asarray(pm))
            self.client_params = stacking.client_lerp(self.client_params,
                                                      avg, pm)
        self.global_params = stacking.client_slice(avg, 0)
        comm = fedavg.comm_bytes_per_round(self.n_params, len(part))
        self._log_round(r, part, losses, [0.0] * K, comm)

    def _round_async(self, r: int, part: List[int]):
        K = self.fed.n_clients
        pm = self._part_mask(part)
        folds, losses = self._local_round(pm if len(part) < K else None)
        self._gather_clients_host()
        scores = self._fold_accuracies(folds)
        # absentees contribute no weight to the aggregate and receive none
        # of it back (scores masked -> their average weight is 0)
        masked_scores = jnp.asarray(np.asarray(scores) * pm)
        synced, layer = async_fl.async_round_update(
            self.client_params, masked_scores, self.shallow_mask, r,
            self.fed.delta, self.fed.min_round)
        # Algorithm 1 lines 17-18: G takes the aggregate then trains on a
        # fold — sliced from the SYNCED tree (where every client received
        # the round's average), not from the lerped one below where an
        # absent client 0 would hand G its stale params
        self.global_params = stacking.client_slice(synced, 0)
        if len(part) < K:
            synced = stacking.client_lerp(self.client_params, synced, pm)
        self.client_params = synced
        self._train_single(self.folds.pop())
        n_sh, n_dp = async_fl.count_params_by_mask(self.global_params,
                                                   self.shallow_mask)
        comm = async_fl.comm_bytes_per_round(n_sh, n_dp, len(part), layer)
        self._log_round(r, part, losses, [0.0] * K, comm, layer=layer)

    # -- checkpoint/resume -------------------------------------------------
    def save_state(self, path: str) -> None:
        """Full federated state through ``repro.checkpoint``: the
        client-stacked params + opt, the global model, the PRNG key, and
        the round counter / fold cursor / plan seed needed to make a
        resumed run bitwise-identical to an uninterrupted one."""
        state = {
            "client_params": self.client_params,
            "client_opts": self.client_opts,
            "global_params": self.global_params,
            "global_opt": self.global_opt,
            "key": jax.random.key_data(self.key)
            if jnp.issubdtype(self.key.dtype, jax.dtypes.prng_key)
            else self.key,
        }
        meta = {
            "engine": "federated",
            "method": self.fed.method,
            "n_clients": self.fed.n_clients,
            "n_rounds": self.fed.rounds,
            "pool_n": len(self.labels),
            "round": self._next_round,
            "plan_seed": self._plan_seed,
            "scheduler": self.folds.state(),
            "total_comm_bytes": self.history.total_comm_bytes,
            "rounds": [dataclasses.asdict(rl) for rl in self.history.rounds],
        }
        checkpoint.save(path, state, meta)

    def restore_state(self, path: str) -> None:
        """Load a ``save_state`` checkpoint into this trainer (must be
        constructed with the same config and data pool)."""
        state, meta = checkpoint.restore(path)
        if meta.get("method") != self.fed.method or \
                meta.get("n_clients") != self.fed.n_clients:
            raise ValueError(
                f"checkpoint ({meta.get('method')}, K={meta.get('n_clients')})"
                f" != config ({self.fed.method}, K={self.fed.n_clients})")
        # fold partition is deterministic in (labels, K, rounds, seed); a
        # different schedule/pool would silently resume on the wrong folds
        if meta.get("n_rounds", self.fed.rounds) != self.fed.rounds or \
                meta.get("pool_n", len(self.labels)) != len(self.labels):
            raise ValueError(
                f"checkpoint schedule (rounds={meta.get('n_rounds')}, "
                f"pool={meta.get('pool_n')}) != config "
                f"(rounds={self.fed.rounds}, pool={len(self.labels)}); "
                "resume needs the same fold partition — save with the full "
                "round budget and stop early via run(until=...)")
        self.client_params = state["client_params"]
        self.client_opts = state["client_opts"]
        self.global_params = state["global_params"]
        self.global_opt = state["global_opt"]
        self.key = jnp.asarray(state["key"])
        self._next_round = int(meta["round"])
        self._plan_seed = int(meta["plan_seed"])
        self.folds.load_state(meta["scheduler"])
        self.history = History(
            rounds=[RoundLog(**d) for d in meta.get("rounds", [])],
            total_comm_bytes=int(meta.get("total_comm_bytes", 0)))

    # -- final eval (paper Table II / Fig. 3) ------------------------------
    def evaluate(self, test_images: np.ndarray, test_labels: np.ndarray):
        self._round_idx = self.fed.rounds                  # eval phase
        self._gather_clients_host()
        self.history.client_test_acc = [
            float(a) for a in self._accuracy_chunked(
                self.client_params, test_images, test_labels)]
        gp = stacking.expand_stack(self.global_params)
        self.history.global_test_acc = float(self._accuracy_chunked(
            gp, test_images, test_labels)[0])
        return self.history
