"""Back-compat shim: the paper's Algorithm-1 trainer as a thin wrapper
over the unified session API.

The engine itself now lives in two composable pieces:

  - ``core.populations.vision.VisionClients`` — the stacked-VisionNet
    client population and its jitted round programs (vmapped local scan,
    fused mutual scan, vmapped predict; optionally device-sharded over a
    ``clients`` mesh),
  - ``core.strategies`` — what crosses the wire per round (``dml`` /
    ``fedavg`` / ``async``), each with its comm-bytes formula,

composed by ``core.api.Federation`` (one participation sampler, fold
discipline, history, comm ledger and checkpoint schema for every
strategy).  ``FederatedTrainer`` maps the flat ``FederatedConfig`` onto
that composition and delegates — results are bitwise-identical to the
pre-API engine (tests/test_api.py), and ``save_state`` files round-trip
between the shim and ``Federation`` unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np

from repro.configs.visionnet import VisionNetConfig
from repro.core.api import Federation, History, RoundLog  # noqa: F401
from repro.core.populations.vision import VisionClients
from repro.core.strategies import DML, AsyncWeights, FedAvg


@dataclass
class FederatedConfig:
    method: str = "dml"               # dml | fedavg | async
    n_clients: int = 5
    rounds: int = 12
    local_epochs: int = 2
    batch_size: int = 32
    lr: float = 0.05
    momentum: float = 0.9
    clip_norm: float = 1.0        # the Eq.-1 KL term spikes at sharing time
                                  # (paper Fig. 4c); clipping keeps SGD stable
    # dml
    kl_weight: float = 1.0
    mutual_epochs: int = 1
    # async
    delta: int = 3
    min_round: int = 5
    # partial participation: sample M <= K clients per round (0 -> all K)
    participation: int = 0
    # non-IID client data (paper §VI future work): Dirichlet(alpha) class
    # skew per client; 0 -> IID stratified folds (the paper's setting)
    non_iid_alpha: float = 0.0
    seed: int = 0
    eval_batch: int = 256

    def strategy(self):
        """The sharing strategy this config names."""
        if self.method == "dml":
            return DML(kl_weight=self.kl_weight,
                       mutual_epochs=self.mutual_epochs)
        if self.method == "fedavg":
            return FedAvg()
        if self.method == "async":
            return AsyncWeights(delta=self.delta, min_round=self.min_round)
        raise ValueError(self.method)


class FederatedTrainer:
    """Legacy facade: ``Federation(VisionClients(...), cfg.strategy())``.

    ``mesh``: optional jax Mesh with a ``clients`` axis — the round's two
    training programs then run device-sharded over the client axis
    (bitwise-identical results; see the population's program docstrings).
    """

    def __init__(self, vn_cfg: VisionNetConfig, fed_cfg: FederatedConfig,
                 train_images: np.ndarray, train_labels: np.ndarray,
                 mesh=None):
        self.vn_cfg = vn_cfg
        self.fed = fed_cfg
        population = VisionClients(
            vn_cfg, train_images, train_labels,
            n_clients=fed_cfg.n_clients, rounds=fed_cfg.rounds,
            local_epochs=fed_cfg.local_epochs,
            batch_size=fed_cfg.batch_size, lr=fed_cfg.lr,
            momentum=fed_cfg.momentum, clip_norm=fed_cfg.clip_norm,
            non_iid_alpha=fed_cfg.non_iid_alpha, seed=fed_cfg.seed,
            eval_batch=fed_cfg.eval_batch, mesh=mesh)
        self.session = Federation(population, fed_cfg.strategy(),
                                  participation=fed_cfg.participation)

    # -- state views (everything tests/benchmarks historically reached) ----
    @property
    def _pop(self) -> VisionClients:
        return self.session.population

    @property
    def history(self) -> History:
        return self.session.history

    @property
    def client_params(self):
        return self._pop.client_params

    @property
    def client_opts(self):
        return self._pop.client_opts

    @property
    def global_params(self):
        return self._pop.global_params

    @property
    def global_opt(self):
        return self._pop.global_opt

    @property
    def dispatch_log(self):
        return self._pop.dispatch_log

    @property
    def folds(self):
        return self._pop.folds

    @property
    def mesh(self):
        return self._pop.mesh

    @property
    def n_params(self) -> int:
        return self._pop.n_params

    def participants(self, r: int) -> List[int]:
        return self.session.participants(r)

    # -- the session API ----------------------------------------------------
    def run(self, until: int = 0) -> History:
        return self.session.run(until=until)

    def evaluate(self, test_images: np.ndarray,
                 test_labels: np.ndarray) -> History:
        return self.session.evaluate(split=(test_images, test_labels))

    def save_state(self, path: str) -> None:
        self.session.save_state(path)

    def restore_state(self, path: str) -> None:
        self.session.restore_state(path)
