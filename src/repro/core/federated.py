"""Algorithm 1 — the paper's federated loop for the VisionNet case study.

Three selectable frameworks under identical conditions (paper §III.B.3:
same architecture, same per-round data size, same epochs, IID folds):

  - 'fedavg': vanilla FL — full weight averaging every round
  - 'async' : asynchronous weight-updating FL — metric-weighted average,
              shallow every round / deep every delta-th round, plus a
              server-side global model trained on a global fold
  - 'dml'   : the proposed framework — clients share only predictions on a
              rotating public fold and descend Eq. 1
              (BCE + avg KL vs the received, fixed predictions)

Clients are a *stacked* pytree (leading axis K — ``core.stacking``, the
same client-axis layout the mesh-scale path shards over pods) and a full
round executes as a handful of jitted programs instead of O(K · batches)
Python-dispatched calls:

  _local_scan     vmap over clients of lax.scan over the fixed-shape
                  (K, T, B) batch plan from ``data.federated``
  _mutual_scan    all mutual epochs fused: dropout-free share + Eq.-1
                  descent for all K clients (``mutual.bernoulli_mutual_loss``)
  _predict_stacked  vmapped inference — sharing, scores, and eval

Communication bytes are accounted per round for the bandwidth claim.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.configs.visionnet import VisionNetConfig
from repro.core import async_fl, fedavg, stacking
from repro.core.mutual import bernoulli_mutual_loss
from repro.data.federated import (FoldScheduler, NonIIDScheduler,
                                  round_batch_indices, sample_participants)
from repro.models.visionnet import (bce_loss, init_visionnet,
                                    shallow_deep_split, visionnet_forward)
from repro.optim import SGDConfig, sgd_init, sgd_update


@dataclass
class FederatedConfig:
    method: str = "dml"               # dml | fedavg | async
    n_clients: int = 5
    rounds: int = 12
    local_epochs: int = 2
    batch_size: int = 32
    lr: float = 0.05
    momentum: float = 0.9
    clip_norm: float = 1.0        # the Eq.-1 KL term spikes at sharing time
                                  # (paper Fig. 4c); clipping keeps SGD stable
    # dml
    kl_weight: float = 1.0
    mutual_epochs: int = 1
    # async
    delta: int = 3
    min_round: int = 5
    # partial participation: sample M <= K clients per round (0 -> all K);
    # non-participants are excluded from the Eq.-2 average via masking and
    # keep their params/opt untouched; comm costs scale with M
    participation: int = 0
    # non-IID client data (paper §VI future work): Dirichlet(alpha) class
    # skew per client; 0 -> IID stratified folds (the paper's setting)
    non_iid_alpha: float = 0.0
    seed: int = 0
    eval_batch: int = 256


@dataclass
class RoundLog:
    round: int
    client_loss: List[float]
    kl_loss: List[float]
    comm_bytes: int
    layer: Optional[str] = None
    participants: Optional[List[int]] = None      # None -> full participation


@dataclass
class History:
    rounds: List[RoundLog] = field(default_factory=list)
    client_test_acc: List[float] = field(default_factory=list)
    global_test_acc: float = 0.0
    total_comm_bytes: int = 0


# ---------------------------------------------------------------------------
# jitted programs — each one covers ALL K clients in a single dispatch


def _masked_lerp(old, new, w):
    """Apply ``new`` only where the step is real (w=1); padding keeps old."""
    return jax.tree.map(lambda a, b: w * b + (1 - w) * a, old, new)


@functools.partial(jax.jit, static_argnames=("vn_cfg", "sgd_cfg",
                                             "conv_impl"))
def _local_scan(stacked_params, stacked_opt, images, labels, masks, keys,
                vn_cfg: VisionNetConfig, sgd_cfg: SGDConfig,
                conv_impl: str = "fused"):
    """Local epochs for all clients: vmap(client) of scan(batch plan).

    images (K,T,B,H,W,C) · labels (K,T,B) · masks (K,T) · keys (K,T,2).
    Returns (stacked_params, stacked_opt, mean BCE per client (K,)).
    """

    def one_client(params, opt, imgs, labs, w, ks):
        def body(carry, xs):
            p, o = carry
            im, la, wi, k = xs

            def loss_fn(q):
                probs = visionnet_forward(q, vn_cfg, im, train=True,
                                          dropout_key=k,
                                          conv_impl=conv_impl)
                return bce_loss(probs, la)

            loss, grads = jax.value_and_grad(loss_fn)(p)
            p2, o2, _ = sgd_update(p, grads, o, sgd_cfg)
            p2 = _masked_lerp(p, p2, wi)
            o2 = {"vel": _masked_lerp(o["vel"], o2["vel"], wi),
                  "step": o["step"] + wi.astype(jnp.int32)}
            return (p2, o2), loss * wi

        (params, opt), losses = jax.lax.scan(body, (params, opt),
                                             (imgs, labs, w, ks))
        return params, opt, jnp.sum(losses) / jnp.maximum(jnp.sum(w), 1.0)

    return jax.vmap(one_client)(stacked_params, stacked_opt, images, labels,
                                masks, keys)


@functools.partial(jax.jit, static_argnames=("vn_cfg", "sgd_cfg",
                                             "kl_weight", "conv_impl"))
def _mutual_scan(stacked_params, stacked_opt, pub_images, pub_labels, keys,
                 part_mask, vn_cfg: VisionNetConfig, sgd_cfg: SGDConfig,
                 kl_weight: float, conv_impl: str = "fused"):
    """All mutual epochs for all K clients, fused into one program.

    keys (E, K, 2) · part_mask (K,) 0/1.  Per epoch: every participant
    shares its dropout-free predictions on the public fold (what actually
    goes over the wire), then descends Eq. 1 — BCE + kl_weight · KLD vs the
    received tensor held fixed (``bernoulli_mutual_loss``).  Partial
    participation masks absentees out of the Eq.-2 average AND out of the
    update (their params/opt ride through unchanged).  Returns the final
    epoch's per-client (total loss, bce, kld), each (K,).
    """

    def epoch(carry, ks):
        params, opt = carry
        shared = jax.vmap(
            lambda q: visionnet_forward(q, vn_cfg, pub_images,
                                        train=False))(params)       # (K,B)

        def total_loss(sp):
            live = jax.vmap(
                lambda q, k: visionnet_forward(q, vn_cfg, pub_images,
                                               train=True, dropout_key=k,
                                               conv_impl=conv_impl)
            )(sp, ks)                                               # (K,B)
            bce = jax.vmap(lambda pr: bce_loss(pr, pub_labels))(live)
            kld = bernoulli_mutual_loss(live, fixed_probs=shared,
                                        part_mask=part_mask)        # (K,)
            return (jnp.sum(bce * part_mask) + kl_weight * jnp.sum(kld),
                    (bce, kld))

        (_, (bce, kld)), grads = jax.value_and_grad(
            total_loss, has_aux=True)(params)
        # per-client update so grad clipping stays per client, exactly as
        # in the per-client loop this replaces
        new_p, new_o, _ = jax.vmap(
            lambda q, g, o: sgd_update(q, g, o, sgd_cfg))(params, grads, opt)
        params = jax.vmap(_masked_lerp)(params, new_p, part_mask)
        opt = {"vel": jax.vmap(_masked_lerp)(opt["vel"], new_o["vel"],
                                             part_mask),
               "step": opt["step"] + part_mask.astype(jnp.int32)}
        return (params, opt), (bce + kl_weight * kld, bce, kld)

    (stacked_params, stacked_opt), (loss, bce, kld) = jax.lax.scan(
        epoch, (stacked_params, stacked_opt), keys)
    return stacked_params, stacked_opt, (loss[-1], bce[-1], kld[-1])


@functools.partial(jax.jit, static_argnames=("vn_cfg",))
def _predict_stacked(stacked_params, images, vn_cfg: VisionNetConfig):
    """Vmapped inference on a SHARED batch: (K-stacked params, (B,...)) ->
    (K, B) probabilities.  The sharing / eval / accuracy path."""
    return jax.vmap(lambda p: visionnet_forward(p, vn_cfg, images,
                                                train=False))(stacked_params)


@functools.partial(jax.jit, static_argnames=("vn_cfg",))
def _accuracy_scan(stacked_params, images, labels, masks,
                   vn_cfg: VisionNetConfig):
    """Per-client accuracy on per-client (padded) data:
    images (K,N,H,W,C) · labels (K,N) · masks (K,N) -> (K,)."""
    probs = jax.vmap(
        lambda p, im: visionnet_forward(p, vn_cfg, im, train=False)
    )(stacked_params, images)
    hit = ((probs > 0.5) == (labels > 0.5)).astype(jnp.float32)
    return jnp.sum(hit * masks, axis=1) / jnp.maximum(
        jnp.sum(masks, axis=1), 1.0)


# ---------------------------------------------------------------------------
# engine

class FederatedTrainer:
    """Runs Algorithm 1 on a (train_images, train_labels) pool."""

    def __init__(self, vn_cfg: VisionNetConfig, fed_cfg: FederatedConfig,
                 train_images: np.ndarray, train_labels: np.ndarray):
        self.vn_cfg = vn_cfg
        self.fed = fed_cfg
        self.images = train_images
        self.labels = train_labels
        self.sgd_cfg = SGDConfig(lr=fed_cfg.lr, momentum=fed_cfg.momentum,
                                 clip_norm=fed_cfg.clip_norm)
        self.key = jax.random.PRNGKey(fed_cfg.seed)
        self._plan_seed = fed_cfg.seed * 100_003 + 17
        # (round, program) pairs — one entry per jitted dispatch, so tests
        # can assert the engine really is a handful of programs per round
        self.dispatch_log: List[Tuple[int, str]] = []
        self._round_idx = -1                      # -1 = init phase
        # Algorithm 1 line 1: Fold <- (1+Clients) x Rounds + 1
        if fed_cfg.non_iid_alpha > 0:
            self.folds = NonIIDScheduler(train_labels, fed_cfg.n_clients,
                                         fed_cfg.rounds,
                                         alpha=fed_cfg.non_iid_alpha,
                                         seed=fed_cfg.seed)
        else:
            self.folds = FoldScheduler(train_labels, fed_cfg.n_clients,
                                       fed_cfg.rounds, seed=fed_cfg.seed)
        # line 3/6: global model trained on public fold
        self.key, kg = jax.random.split(self.key)
        self.global_params = init_visionnet(kg, vn_cfg)
        self.global_opt = sgd_init(self.global_params)
        self._train_single(self.folds.pop())
        # lines 7-8: clients start from G
        K = fed_cfg.n_clients
        self.client_params = stacking.broadcast_stack(self.global_params, K)
        self.client_opts = stacking.stacked_sgd_init(self.client_params)
        self.n_params = sum(p.size for p in jax.tree.leaves(self.global_params))
        self.shallow_mask = shallow_deep_split(self.global_params)
        self.history = History()
        self._next_round = 0

    # -- helpers ----------------------------------------------------------
    def participants(self, r: int) -> List[int]:
        """The M clients sampled for round r (stateless in r — resume-safe).
        Full participation returns all K."""
        return sample_participants(self.fed.n_clients, self.fed.participation,
                                   self.fed.seed, r)

    def _part_mask(self, part: List[int]) -> np.ndarray:
        mask = np.zeros((self.fed.n_clients,), np.float32)
        mask[part] = 1.0
        return mask

    def _next_plan_seed(self) -> int:
        self._plan_seed += 1
        return self._plan_seed

    def _split_keys(self, *shape) -> jax.Array:
        """Dropout keys for a whole program at once: (*shape, 2) uint32."""
        self.key, sub = jax.random.split(self.key)
        n = int(np.prod(shape))
        return jax.random.split(sub, n).reshape(*shape, 2)

    def _gather(self, idx: np.ndarray):
        return jnp.asarray(self.images[idx]), jnp.asarray(self.labels[idx])

    def _train_single(self, fold: np.ndarray) -> float:
        """Global-model training = the SAME scan program with K=1."""
        idx, mask = round_batch_indices([fold], self.fed.local_epochs,
                                        self.fed.batch_size,
                                        seed=self._next_plan_seed())
        if idx.shape[1] == 0:
            return 0.0
        imgs, labs = self._gather(idx)
        keys = self._split_keys(1, idx.shape[1])
        gp = stacking.expand_stack(self.global_params)
        go = stacking.expand_stack(self.global_opt)
        gp, go, losses = _local_scan(gp, go, imgs, labs, jnp.asarray(mask),
                                     keys, self.vn_cfg, self.sgd_cfg,
                                     conv_impl="native")
        self.dispatch_log.append((self._round_idx, "local_scan"))
        self.global_params = stacking.client_slice(gp, 0)
        self.global_opt = stacking.client_slice(go, 0)
        return float(losses[0])

    def _local_round(self, part_mask: Optional[np.ndarray] = None):
        """Pop K client folds and run every client's local epochs in ONE
        vmapped scan dispatch.  Returns (folds, per-client mean loss).

        ``part_mask`` (K,) 0/1 zeroes the whole batch plan of absent
        clients — their params/opt ride through the scan untouched (the
        masked-lerp padding path), exactly as if they never trained.
        """
        K = self.fed.n_clients
        folds, idx, mask = self.folds.pop_round(
            K, self.fed.local_epochs, self.fed.batch_size,
            seed=self._next_plan_seed())
        if idx.shape[1] == 0:
            return folds, [0.0] * K
        if part_mask is not None:
            mask = mask * part_mask[:, None]
        imgs, labs = self._gather(idx)
        keys = self._split_keys(K, idx.shape[1])
        self.client_params, self.client_opts, losses = _local_scan(
            self.client_params, self.client_opts, imgs, labs,
            jnp.asarray(mask), keys, self.vn_cfg, self.sgd_cfg,
            conv_impl="fused" if K > 1 else "native")
        self.dispatch_log.append((self._round_idx, "local_scan"))
        return folds, [float(x) for x in np.asarray(losses)]

    def _fold_accuracies(self, folds) -> List[float]:
        """Each client scored on its OWN fold — one vmapped dispatch over a
        padded (K, N) stack (the async baseline's weighting metric)."""
        n = max(max((len(f) for f in folds), default=0), 1)
        K = len(folds)
        idx = np.zeros((K, n), np.int64)
        mask = np.zeros((K, n), np.float32)
        for c, f in enumerate(folds):
            idx[c, :len(f)] = f
            mask[c, :len(f)] = 1.0
        imgs, labs = self._gather(idx)
        acc = _accuracy_scan(self.client_params, imgs, labs,
                             jnp.asarray(mask), self.vn_cfg)
        self.dispatch_log.append((self._round_idx, "accuracy_scan"))
        return [float(a) for a in np.asarray(acc)]

    def _accuracy_chunked(self, stacked_params, images, labels) -> np.ndarray:
        """All clients' accuracy on a SHARED dataset via the vmapped
        predict, eval_batch examples at a time.  Returns (K,)."""
        K = jax.tree.leaves(stacked_params)[0].shape[0]
        correct = np.zeros((K,), np.int64)
        for i in range(0, len(images), self.fed.eval_batch):
            probs = _predict_stacked(stacked_params,
                                     jnp.asarray(images[i:i + self.fed.eval_batch]),
                                     self.vn_cfg)
            self.dispatch_log.append((self._round_idx, "predict"))
            correct += np.sum((np.asarray(probs) > 0.5) ==
                              labels[None, i:i + self.fed.eval_batch], axis=1)
        return correct / len(images)

    # -- rounds -----------------------------------------------------------
    def run(self, until: int = 0) -> History:
        """Run rounds up to ``until`` (0 -> cfg.rounds).  Picks up from the
        round counter, so save_state/restore_state mid-run and a second
        ``run()`` continue exactly where the checkpoint left off."""
        stop = until or self.fed.rounds
        for r in range(self._next_round, min(stop, self.fed.rounds)):
            self._round_idx = r
            part = self.participants(r)
            if self.fed.method == "dml":
                self._round_dml(r, part)
            elif self.fed.method == "fedavg":
                self._round_fedavg(r, part)
            elif self.fed.method == "async":
                self._round_async(r, part)
            else:
                raise ValueError(self.fed.method)
            self._next_round = r + 1
        return self.history

    def _log_round(self, r, part, losses, kls, comm, layer=None):
        full = len(part) == self.fed.n_clients
        self.history.total_comm_bytes += comm
        self.history.rounds.append(RoundLog(
            r, losses, kls, comm, layer=layer,
            participants=None if full else part))

    def _round_dml(self, r: int, part: List[int]):
        K = self.fed.n_clients
        pm = self._part_mask(part)
        _, local_losses = self._local_round(pm if len(part) < K else None)
        # public fold: rotating common test set from the server
        pub = self.folds.pop()
        kl_losses = [0.0] * K
        comm = 0
        if self.fed.mutual_epochs > 0 and len(part) >= 2:
            pub_imgs = jnp.asarray(self.images[pub])
            pub_labs = jnp.asarray(self.labels[pub])
            keys = self._split_keys(self.fed.mutual_epochs, K)
            self.client_params, self.client_opts, (loss, _, kld) = \
                _mutual_scan(self.client_params, self.client_opts, pub_imgs,
                             pub_labs, keys, jnp.asarray(pm), self.vn_cfg,
                             self.sgd_cfg, self.fed.kl_weight,
                             conv_impl="fused" if K > 1 else "native")
            self.dispatch_log.append((r, "mutual_scan"))
            local_losses = [float(x) * m for x, m in
                            zip(np.asarray(loss), pm)]
            kl_losses = [float(x) for x in np.asarray(kld)]
            # inference + sharing: each PARTICIPANT ships (B_pub,)
            # probabilities up and receives the (M, B_pub) broadcast down,
            # EVERY epoch — bytes scale with M, not K
            comm = self.fed.mutual_epochs * 2 * len(part) * len(pub) * 4
        self._log_round(r, part, local_losses, kl_losses, comm)

    def _round_fedavg(self, r: int, part: List[int]):
        K = self.fed.n_clients
        pm = self._part_mask(part)
        _, losses = self._local_round(pm if len(part) < K else None)
        self.folds.pop()                                  # global fold unused
        if len(part) == K:
            self.client_params = fedavg.average_weights(self.client_params)
            avg = self.client_params
        else:
            # server averages the M participants; only they receive the
            # broadcast back (absentees are offline this round)
            avg = fedavg.weighted_average_weights(self.client_params,
                                                  jnp.asarray(pm))
            self.client_params = stacking.client_lerp(self.client_params,
                                                      avg, pm)
        self.global_params = stacking.client_slice(avg, 0)
        comm = fedavg.comm_bytes_per_round(self.n_params, len(part))
        self._log_round(r, part, losses, [0.0] * K, comm)

    def _round_async(self, r: int, part: List[int]):
        K = self.fed.n_clients
        pm = self._part_mask(part)
        folds, losses = self._local_round(pm if len(part) < K else None)
        scores = self._fold_accuracies(folds)
        # absentees contribute no weight to the aggregate and receive none
        # of it back (scores masked -> their average weight is 0)
        masked_scores = jnp.asarray(np.asarray(scores) * pm)
        synced, layer = async_fl.async_round_update(
            self.client_params, masked_scores, self.shallow_mask, r,
            self.fed.delta, self.fed.min_round)
        # Algorithm 1 lines 17-18: G takes the aggregate then trains on a
        # fold — sliced from the SYNCED tree (where every client received
        # the round's average), not from the lerped one below where an
        # absent client 0 would hand G its stale params
        self.global_params = stacking.client_slice(synced, 0)
        if len(part) < K:
            synced = stacking.client_lerp(self.client_params, synced, pm)
        self.client_params = synced
        self._train_single(self.folds.pop())
        n_sh, n_dp = async_fl.count_params_by_mask(self.global_params,
                                                   self.shallow_mask)
        comm = async_fl.comm_bytes_per_round(n_sh, n_dp, len(part), layer)
        self._log_round(r, part, losses, [0.0] * K, comm, layer=layer)

    # -- checkpoint/resume -------------------------------------------------
    def save_state(self, path: str) -> None:
        """Full federated state through ``repro.checkpoint``: the
        client-stacked params + opt, the global model, the PRNG key, and
        the round counter / fold cursor / plan seed needed to make a
        resumed run bitwise-identical to an uninterrupted one."""
        state = {
            "client_params": self.client_params,
            "client_opts": self.client_opts,
            "global_params": self.global_params,
            "global_opt": self.global_opt,
            "key": jax.random.key_data(self.key)
            if jnp.issubdtype(self.key.dtype, jax.dtypes.prng_key)
            else self.key,
        }
        meta = {
            "engine": "federated",
            "method": self.fed.method,
            "n_clients": self.fed.n_clients,
            "n_rounds": self.fed.rounds,
            "pool_n": len(self.labels),
            "round": self._next_round,
            "plan_seed": self._plan_seed,
            "scheduler": self.folds.state(),
            "total_comm_bytes": self.history.total_comm_bytes,
            "rounds": [dataclasses.asdict(rl) for rl in self.history.rounds],
        }
        checkpoint.save(path, state, meta)

    def restore_state(self, path: str) -> None:
        """Load a ``save_state`` checkpoint into this trainer (must be
        constructed with the same config and data pool)."""
        state, meta = checkpoint.restore(path)
        if meta.get("method") != self.fed.method or \
                meta.get("n_clients") != self.fed.n_clients:
            raise ValueError(
                f"checkpoint ({meta.get('method')}, K={meta.get('n_clients')})"
                f" != config ({self.fed.method}, K={self.fed.n_clients})")
        # fold partition is deterministic in (labels, K, rounds, seed); a
        # different schedule/pool would silently resume on the wrong folds
        if meta.get("n_rounds", self.fed.rounds) != self.fed.rounds or \
                meta.get("pool_n", len(self.labels)) != len(self.labels):
            raise ValueError(
                f"checkpoint schedule (rounds={meta.get('n_rounds')}, "
                f"pool={meta.get('pool_n')}) != config "
                f"(rounds={self.fed.rounds}, pool={len(self.labels)}); "
                "resume needs the same fold partition — save with the full "
                "round budget and stop early via run(until=...)")
        self.client_params = state["client_params"]
        self.client_opts = state["client_opts"]
        self.global_params = state["global_params"]
        self.global_opt = state["global_opt"]
        self.key = jnp.asarray(state["key"])
        self._next_round = int(meta["round"])
        self._plan_seed = int(meta["plan_seed"])
        self.folds.load_state(meta["scheduler"])
        self.history = History(
            rounds=[RoundLog(**d) for d in meta.get("rounds", [])],
            total_comm_bytes=int(meta.get("total_comm_bytes", 0)))

    # -- final eval (paper Table II / Fig. 3) ------------------------------
    def evaluate(self, test_images: np.ndarray, test_labels: np.ndarray):
        self._round_idx = self.fed.rounds                  # eval phase
        self.history.client_test_acc = [
            float(a) for a in self._accuracy_chunked(
                self.client_params, test_images, test_labels)]
        gp = stacking.expand_stack(self.global_params)
        self.history.global_test_acc = float(self._accuracy_chunked(
            gp, test_images, test_labels)[0])
        return self.history
