"""Vanilla federated learning (FedAvg, McMahan et al.) — weight baseline #1.

Works on *client-stacked* pytrees (leading axis K on every leaf): averaging
is a mean over axis 0 broadcast back — exactly an all-reduce over the client
mesh axis when the stack is sharded client-wise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.stacking import stack_params, unstack_params  # noqa: F401
# (re-exported: the stacked-layout helpers live in core.stacking, shared
# with the mesh-scale engine)


def average_weights(stacked_params):
    """Mean over the client axis, broadcast back.  (FedAvg aggregation.)"""
    def avg(p):
        mean = jnp.mean(p.astype(jnp.float32), axis=0, keepdims=True)
        return jnp.broadcast_to(mean, p.shape).astype(p.dtype)
    return jax.tree.map(avg, stacked_params)


def weighted_average_weights(stacked_params, scores):
    """Score-weighted FedAvg (the paper's [4] ``preprocessWeights``).

    scores: (K,) non-negative client metrics (e.g. accuracy); weights are
    scores normalised to sum 1.
    """
    w = jnp.asarray(scores, jnp.float32)
    w = w / jnp.maximum(jnp.sum(w), 1e-9)

    def avg(p):
        pf = p.astype(jnp.float32)
        wb = w.reshape((-1,) + (1,) * (p.ndim - 1))
        mean = jnp.sum(pf * wb, axis=0, keepdims=True)
        return jnp.broadcast_to(mean, p.shape).astype(p.dtype)
    return jax.tree.map(avg, stacked_params)


def comm_bytes_per_round(n_params: int, n_clients: int,
                         bytes_per_param: int = 4) -> int:
    """Up + down traffic of one FedAvg round (every client ships all params
    to the server and receives the average back)."""
    return 2 * n_clients * n_params * bytes_per_param
