"""``Federation`` — the one session object behind every federated run.

The paper's core claim is that *what crosses the wire* (predictions vs
weights, Eq. 1/2 vs FedAvg/async) is a swappable choice with
accuracy/bandwidth/privacy consequences.  This module makes the choice a
constructor argument instead of a trainer class:

    Federation(population, strategy, participation=0)

composes a sharing **strategy** (``core.strategies``: DML / SparseDML /
FedAvg / AsyncWeights — the protocol + comm formula) with a client
**population** (``core.populations``: stacked VisionNet, heterogeneous
model registry, LLM-scale stacked steps — the models + execution
backend, single-device vmap or a ``clients`` mesh).  The session owns
everything the three legacy engines used to duplicate:

  - ONE participation sampler (``data.federated.sample_participants``,
    stateless in the round index — resume-safe),
  - ONE round loop (local_phase -> round_payload -> combine) over the
    population's shared ``FoldScheduler`` discipline,
  - ONE ``History``/``RoundLog`` shape and comm-bytes ledger,
  - ONE checkpoint schema (``save_state``/``restore_state`` through
    ``repro.checkpoint`` — files written by the legacy
    ``FederatedTrainer``/``HeteroTrainer`` restore unchanged),
  - ONE ``evaluate(split=...)`` entry point (held-out dataset for the
    vision population, common eval fold for hetero/LM).

``core.federated.FederatedTrainer`` and ``core.hetero.HeteroTrainer``
are thin back-compat shims over this class and reproduce their
pre-refactor results bitwise (tests/test_api.py holds params, scores
and comm accounting to exact equality).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro import checkpoint
from repro.data.federated import sample_participants


@dataclass
class RoundLog:
    """One round's ledger entry (superset of the legacy engines' logs:
    ``layer`` is async-only, ``public_ce`` prediction-sharing-only)."""
    round: int
    client_loss: List[float]
    kl_loss: List[float]
    comm_bytes: int
    layer: Optional[str] = None
    participants: Optional[List[int]] = None      # None -> full participation
    public_ce: Optional[List[float]] = None


@dataclass
class History:
    """Session history shared by every strategy x population pairing."""
    rounds: List[RoundLog] = field(default_factory=list)
    client_test_acc: List[float] = field(default_factory=list)   # vision eval
    global_test_acc: float = 0.0                                 # vision eval
    client_eval_loss: List[float] = field(default_factory=list)  # lm eval
    total_comm_bytes: int = 0


class Federation:
    """One federated learning session: strategy x population x rounds.

    ``participation``: sample M <= K clients per round (0 -> all K);
    non-participants train nothing, share nothing, receive nothing, and
    comm costs scale with M.  The sampler is stateless in the round
    index, so a restored session samples exactly the same subsets.
    """

    def __init__(self, population, strategy, participation: int = 0):
        population.validate_strategy(strategy)
        self.population = population
        self.strategy = strategy
        self.participation = participation
        self.history = History()
        self.round = 0                     # next round to run

    # -- derived ----------------------------------------------------------
    @property
    def n_clients(self) -> int:
        return self.population.n_clients

    @property
    def rounds(self) -> int:
        return self.population.rounds

    @property
    def dispatch_log(self):
        return getattr(self.population, "dispatch_log", [])

    def participants(self, r: int) -> List[int]:
        """The M clients sampled for round r (stateless in r — resume-safe).
        Full participation returns all K."""
        return sample_participants(self.n_clients, self.participation,
                                   self.population.seed, r)

    # -- rounds -----------------------------------------------------------
    def run(self, until: int = 0) -> History:
        """Run rounds up to ``until`` (0 -> population.rounds).  Picks up
        from the round counter, so save_state/restore_state mid-run and a
        second ``run()`` continue exactly where the checkpoint left off."""
        stop = until or self.rounds
        for r in range(self.round, min(stop, self.rounds)):
            self._run_round(r)
        return self.history

    def _run_round(self, r: int) -> None:
        pop, strat = self.population, self.strategy
        pop.begin_round(r)
        part = self.participants(r)
        pm = pop.part_mask(part)
        local_losses = strat.local_phase(pop, r, part, pm)
        payload = strat.round_payload(pop, r, part)
        out = strat.combine(pop, r, part, pm, payload) or {}
        comm = strat.comm_bytes(pop, part, payload, out)
        K = self.n_clients
        full = len(part) == K
        self.history.total_comm_bytes += comm
        self.history.rounds.append(RoundLog(
            r,
            out.get("client_loss", local_losses or [0.0] * K),
            out.get("kl_loss", [0.0] * K),
            comm,
            layer=out.get("layer"),
            participants=part if (not full or
                                  pop.log_participants_always) else None,
            public_ce=out.get("public_ce")))
        self.round = r + 1

    # -- eval ----------------------------------------------------------------
    def evaluate(self, split=None) -> History:
        """Population-appropriate final evaluation.

        vision: ``split=(test_images, test_labels)`` — per-client accuracy
        on the unseen dataset (paper Table II) + the global model's.
        hetero / lm: ``split=None`` — per-client loss on the common
        held-out fold every client optimised in Eq. 1.
        """
        return self.population.evaluate(self.history, split)

    # -- checkpoint/resume -------------------------------------------------
    def save_state(self, path: str) -> None:
        """Full session state through ``repro.checkpoint`` — the population
        state (params/opt/PRNG/fold cursor) plus the session's round
        counter, comm ledger and history.  Schema-identical to the legacy
        trainers' ``save_state`` files."""
        meta = {
            **self.population.meta_dict(),
            "method": self.strategy.name,
            "round": self.round,
            "total_comm_bytes": self.history.total_comm_bytes,
            "rounds": [dataclasses.asdict(rl) for rl in self.history.rounds],
        }
        # stateful strategies (e.g. DPDML's accountant + noise key) ride in
        # the JSON meta so resume replays the identical noise/budget stream
        if hasattr(self.strategy, "state_dict"):
            meta["strategy_state"] = self.strategy.state_dict()
        checkpoint.save(path, self.population.state_dict(), meta)

    def export_for_serving(self, path: str) -> None:
        """Write the slim serving artifact: client params only (no
        optimiser moments, PRNG state or fold cursors — typically ~1/3
        the bytes of ``save_state``) plus the meta the serving engine
        needs to rebuild the config (``engine``/``arch``/``n_clients``).
        ``ServeEngine.from_checkpoint`` / ``launch.serve --ckpt`` read
        both this artifact and full ``save_state`` files."""
        state = self.population.state_dict()
        if "client_params" not in state:
            raise ValueError(
                f"population {self.population.engine_name!r} does not "
                "expose a stacked 'client_params' pytree; only the LM "
                "population is servable (hetero checkpoints one pytree "
                "per arch)")
        meta = {k: v for k, v in self.population.meta_dict().items()
                if k in ("engine", "arch", "n_clients")}
        meta["round"] = self.round
        checkpoint.save(path, {"client_params": state["client_params"]},
                        meta)

    def restore_state(self, path: str) -> None:
        """Load a ``save_state`` checkpoint — including files written by
        the pre-API ``FederatedTrainer``/``HeteroTrainer`` — into this
        session (must be constructed with the same config and data pool)."""
        state, meta = checkpoint.restore(path)
        method = meta.get("method", self.strategy.name)
        if method != self.strategy.name:
            raise ValueError(
                f"checkpoint strategy {method!r} != session strategy "
                f"{self.strategy.name!r}")
        self.population.check_meta(meta)
        if "strategy_state" in meta and hasattr(self.strategy,
                                                "load_state_dict"):
            self.strategy.load_state_dict(meta["strategy_state"])
        self.population.load_state_dict(state, meta)
        self.round = int(meta["round"])
        self.history = History(
            rounds=[RoundLog(**_round_kwargs(d))
                    for d in meta.get("rounds", [])],
            total_comm_bytes=int(meta.get("total_comm_bytes", 0)))


def _round_kwargs(d: Dict[str, Any]) -> Dict[str, Any]:
    """Accept round dicts from any schema generation (legacy hetero logs
    have no ``layer``; legacy federated logs no ``public_ce``; unknown
    future keys are dropped rather than crashing the restore)."""
    fields = {f.name for f in dataclasses.fields(RoundLog)}
    return {k: v for k, v in d.items() if k in fields}
