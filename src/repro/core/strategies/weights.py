"""Weight-sharing strategies — the paper's two baselines as protocol
objects: full FedAvg every round, and the asynchronous shallow/deep
schedule of [4].  Both move parameters, so their comm cost scales with
model size (the contrast the paper's bandwidth claim is measured
against) and both are undefined across clients whose pytrees differ —
populations enforce that at session construction.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

from repro.core import async_fl, fedavg
from repro.core.strategies.base import Payload, register


@register
class FedAvg:
    """Vanilla FL: every participant ships all params; the server
    broadcasts the (score-free) average back to the participants."""
    name = "fedavg"

    def local_phase(self, pop, r: int, part: List[int],
                    pm) -> Optional[List[float]]:
        return pop.local_phase(r, part, pm)

    def round_payload(self, pop, r: int, part: List[int]) -> Payload:
        return Payload(kind="weights", data=pop.weights_payload(r))

    def combine(self, pop, r: int, part: List[int], pm,
                payload: Payload) -> Dict[str, Any]:
        pop.fedavg_combine(part, pm)
        return {"ran": True}

    def comm_bytes(self, pop, part: List[int], payload: Payload,
                   out: Dict[str, Any]) -> int:
        return fedavg.comm_bytes_per_round(pop.params_per_client,
                                           len(part))


@register
class AsyncWeights:
    """Asynchronous weight-updating FL: metric-weighted average, shallow
    layers every round, deep layers every ``delta``-th round past
    ``min_round`` (``async_fl.layer_schedule``)."""
    name = "async"

    def __init__(self, delta: int = 3, min_round: int = 5):
        self.delta = int(delta)
        self.min_round = int(min_round)

    def local_phase(self, pop, r: int, part: List[int],
                    pm) -> Optional[List[float]]:
        return pop.local_phase(r, part, pm)

    def round_payload(self, pop, r: int, part: List[int]) -> Payload:
        # the async server also trains a global model on this round's
        # shared fold (Algorithm 1 lines 17-18) — the payload carries it
        return Payload(kind="weights", data=pop.weights_payload(r))

    def combine(self, pop, r: int, part: List[int], pm,
                payload: Payload) -> Dict[str, Any]:
        layer = pop.async_combine(r, part, pm, self.delta, self.min_round,
                                  payload.data)
        return {"ran": True, "layer": layer}

    def comm_bytes(self, pop, part: List[int], payload: Payload,
                   out: Dict[str, Any]) -> int:
        n_shallow, n_deep = pop.async_param_counts()
        return async_fl.comm_bytes_per_round(n_shallow, n_deep, len(part),
                                             out["layer"])
