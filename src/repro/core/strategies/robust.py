"""Byzantine-robust DML variants — Eq. 2 with the mean over received
predictions replaced by a robust consensus.

Plain DML descends the AVERAGE KL to every received prediction, so a
single colluding or sign-flipped payload shifts every honest client's
Eq.-1 gradient.  These strategies aggregate the received predictions
into a coordinate-wise trimmed-mean or median consensus target first
(``mutual.robust_bernoulli_target`` / ``robust_categorical_target``) and
descend ``KL(P_i || target_i)`` — up to f = trim poisoned participants
per round contribute nothing to any position they try to drag.

Degenerate participation is deterministic by contract: M < 2 skips
sharing (like every prediction strategy), and a trimmed mean whose live
sender count n = M - 1 satisfies n - 2·trim < 1 falls back to the
untrimmed masked mean rather than producing an empty average.
"""
from __future__ import annotations

from typing import Any, Dict, List

from repro.core.strategies.base import Payload, register
from repro.core.strategies.dml import DML


class _RobustDML(DML):
    """Shared plumbing: hand the (mode, trim) spec to the population."""
    robust_mode = "trimmed"

    def __init__(self, kl_weight: float = 1.0, mutual_epochs: int = 1,
                 trim: int = 1):
        super().__init__(kl_weight=kl_weight, mutual_epochs=mutual_epochs)
        if trim < 0:
            raise ValueError(f"trim must be >= 0, got {trim}")
        self.trim = int(trim)

    def combine(self, pop, r: int, part: List[int], pm,
                payload: Payload) -> Dict[str, Any]:
        out = pop.mutual_phase(
            r, part, pm, payload, self.kl_weight, self.mutual_epochs,
            sparse_k=0, robust=(self.robust_mode, self.trim))
        payload.positions = int(out.get("positions", 0))
        return out


@register
class TrimmedDML(_RobustDML):
    """Coordinate-wise trimmed-mean consensus: drop the ``trim`` largest
    and smallest received values per shared position, average the rest.
    Tolerates up to ``trim`` poisoned participants per round."""
    name = "trimmed-dml"
    robust_mode = "trimmed"


@register
class MedianDML(_RobustDML):
    """Coordinate-wise median consensus — the maximally-trimmed mean;
    ``trim`` is accepted for CLI symmetry but unused."""
    name = "median-dml"
    robust_mode = "median"
