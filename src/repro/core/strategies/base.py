"""The ``Strategy`` protocol — *what crosses the wire* as a first-class,
swappable choice.

The paper's central axis (and the organizing axis of the "What to Share
in Federated Learning" survey) is the sharing medium: predictions on a
rotating public fold (Eq. 1/2), full weights (FedAvg), partial weights on
a schedule (async), or sparse top-k predictions (bandwidth-constrained
FL).  A :class:`Strategy` packages one such choice — its per-round
orchestration AND its communication-cost formula — independently of the
client population executing it (stacked VisionNet, heterogeneous model
registry, or LLM-scale stacked steps; see ``core.populations``).

One federated round under ``api.Federation`` is always the same four
protocol steps:

    local_phase    each participant trains on its private fold(s)
    round_payload  the strategy declares (and the population materialises)
                   what will cross client boundaries this round
    combine        the cross-client update — Eq.-1 descent against the
                   received predictions, or a weight aggregation
    comm_bytes     the ledger entry for exactly the payload that moved

Populations expose a small capability surface (``local_phase`` /
``mutual_phase`` / ``fedavg_combine`` / ``async_combine`` / payload
metadata); strategies orchestrate those capabilities and own every
protocol hyperparameter (``kl_weight``, ``mutual_epochs``, ``delta``,
``sparse_k``, ...).  Model/optimizer/data configuration stays with the
population — that separation is what makes the strategy x population
matrix composable.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Protocol, runtime_checkable


@dataclass
class Payload:
    """What one round moves across client boundaries.

    kind      'predictions' | 'sparse-predictions' | 'weights'
    data      population-specific payload source (e.g. the public-fold
              index array the predictions are computed on); may be None
    positions number of shared prediction positions (payload size axis);
              filled by ``combine`` for prediction strategies
    """
    kind: str
    data: Any = None
    positions: int = 0
    extra: Dict[str, Any] = field(default_factory=dict)


@runtime_checkable
class Strategy(Protocol):
    """Protocol implemented by every sharing strategy.

    ``name`` doubles as the checkpoint ``method`` tag and the CLI /
    registry id, so it must stay stable across releases.
    """
    name: str

    def local_phase(self, pop, r: int, part: List[int],
                    pm) -> Optional[List[float]]:
        """Participants' local training; returns per-client losses (or
        None when the population fuses local+combine in one program)."""
        ...

    def round_payload(self, pop, r: int, part: List[int]) -> Payload:
        """Materialise this round's payload source (pops the public fold
        for prediction strategies — fold-budget discipline is identical
        across strategies so checkpoints stay schedule-compatible)."""
        ...

    def combine(self, pop, r: int, part: List[int], pm,
                payload: Payload) -> Dict[str, Any]:
        """The cross-client update.  Returns round metrics: any of
        ``client_loss`` / ``kl_loss`` / ``public_ce`` / ``layer`` /
        ``ran`` (whether the payload actually moved)."""
        ...

    def comm_bytes(self, pop, part: List[int], payload: Payload,
                   out: Dict[str, Any]) -> int:
        """Bytes this round's payload moved (up + broadcast down)."""
        ...


STRATEGIES: Dict[str, type] = {}


def register(cls):
    STRATEGIES[cls.name] = cls
    return cls


def get_strategy(name: str, **knobs):
    """Resolve a strategy id ('dml', 'sparse-dml', 'fedavg', 'async') to a
    configured instance; unknown knobs for that strategy are ignored so one
    CLI flag namespace can drive the whole matrix."""
    if name not in STRATEGIES:
        raise ValueError(f"unknown strategy {name!r}; "
                         f"have {sorted(STRATEGIES)}")
    cls = STRATEGIES[name]
    import inspect
    accepted = set(inspect.signature(cls.__init__).parameters)
    return cls(**{k: v for k, v in knobs.items() if k in accepted})
