"""Sharing strategies for ``repro.api.Federation`` — one class per
answer to *what crosses the wire*:

- :class:`DML`          dense prediction sharing (the paper, Eq. 1/2)
- :class:`SparseDML`    top-k prediction sharing (bandwidth-constrained)
- :class:`DPDML`        clipped + Gaussian-noised predictions with a
                        Rényi (ε, δ) accountant (privacy-constrained)
- :class:`TrimmedDML`   trimmed-mean consensus Eq. 2 (Byzantine-robust)
- :class:`MedianDML`    median consensus Eq. 2 (Byzantine-robust)
- :class:`FedAvg`       full weight averaging (baseline #1)
- :class:`AsyncWeights` shallow/deep scheduled weight sharing (baseline #2)

``get_strategy(name, **knobs)`` resolves CLI ids; :class:`Strategy` is
the protocol populations are orchestrated through.
"""
from repro.core.strategies.base import (Payload, STRATEGIES, Strategy,
                                        get_strategy)
from repro.core.strategies.dml import DML, SparseDML
from repro.core.strategies.dp import DPDML
from repro.core.strategies.robust import MedianDML, TrimmedDML
from repro.core.strategies.weights import AsyncWeights, FedAvg

__all__ = ["Strategy", "Payload", "STRATEGIES", "get_strategy",
           "DML", "SparseDML", "DPDML", "TrimmedDML", "MedianDML",
           "FedAvg", "AsyncWeights"]
