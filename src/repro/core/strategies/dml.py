"""Prediction-sharing strategies: the paper's proposal (dense Eq. 1/2
DML) and its bandwidth-constrained variant (sparse top-k sharing).

Dense DML moves, per mutual epoch, every participant's predictions on
the shared public positions up and the (M, positions) broadcast back
down.  SparseDML moves only the top-k (index, log-prob) pairs — bytes
drop by V / (2k) at a small KL-approximation error (the receiver treats
the residual mass as uniform over the tail; ``mutual.sparse_share_bytes``
/ ``mutual.sparse_kl_to_received``).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

from repro.core.mutual import sparse_share_bytes
from repro.core.strategies.base import Payload, register


@register
class DML:
    """The paper's framework: Eq.-1 descent against received predictions.

    ``kl_weight``: weight of the Eq.-2 KLD term in Eq. 1.
    ``mutual_epochs``: share + descend passes per round (sharing happens
    EVERY epoch — comm scales with it).
    """
    name = "dml"
    sparse_k = 0

    def __init__(self, kl_weight: float = 1.0, mutual_epochs: int = 1):
        self.kl_weight = float(kl_weight)
        self.mutual_epochs = int(mutual_epochs)

    def local_phase(self, pop, r: int, part: List[int],
                    pm) -> Optional[List[float]]:
        if getattr(pop, "fused_dml", False):
            return None                      # combine covers local + mutual
        return pop.local_phase(r, part, pm)

    def round_payload(self, pop, r: int, part: List[int]) -> Payload:
        kind = "sparse-predictions" if self.sparse_k else "predictions"
        return Payload(kind=kind, data=pop.public_payload(r))

    def combine(self, pop, r: int, part: List[int], pm,
                payload: Payload) -> Dict[str, Any]:
        out = pop.mutual_phase(r, part, pm, payload, self.kl_weight,
                               self.mutual_epochs, sparse_k=self.sparse_k)
        payload.positions = int(out.get("positions", 0))
        return out

    def comm_bytes(self, pop, part: List[int], payload: Payload,
                   out: Dict[str, Any]) -> int:
        if not out.get("ran"):
            return 0
        # every mutual epoch each of the M participants ships its
        # (positions,) x V-wide predictions up and receives the
        # (M, positions) broadcast down — bytes scale with M, not K,
        # and are independent of any model's parameter count
        per_epoch = 2 * len(part) * payload.positions * \
            pop.bytes_per_position
        return self.mutual_epochs * per_epoch


@register
class SparseDML(DML):
    """Top-k prediction sharing: clients publish only (indices, log-probs)
    of their k most likely classes; the receiver reconstructs ~P with a
    uniform tail.  Needs a categorical prediction space (V classes) —
    Bernoulli-sharing populations reject it at session construction.
    """
    name = "sparse-dml"

    def __init__(self, k: int = 64, kl_weight: float = 1.0,
                 mutual_epochs: int = 1):
        super().__init__(kl_weight=kl_weight, mutual_epochs=mutual_epochs)
        if k <= 0:
            raise ValueError(f"SparseDML needs k > 0, got {k}")
        self.sparse_k = int(k)

    def comm_bytes(self, pop, part: List[int], payload: Payload,
                   out: Dict[str, Any]) -> int:
        if not out.get("ran"):
            return 0
        return self.mutual_epochs * sparse_share_bytes(
            len(part), payload.positions, self.sparse_k)
