"""DP-DML — the paper's prediction-sharing protocol with a differential
privacy guarantee on what crosses the wire.

Every mutual epoch each participant's public-set predictions are
L2-clipped and Gaussian-noised (``privacy.dp``) BEFORE the all-gather,
so the only tensor that ever leaves a client is an (ε, δ)-DP release;
the strategy owns the Rényi accountant (``privacy.accountant``) that
composes those releases across epochs and rounds into the session's
privacy curve.  Comm bytes are identical to dense DML — noise is free on
the wire — which is the Kerkouche-style low-bandwidth-DP argument: a
low-dimensional prediction payload needs far less noise per unit of
utility than a parameter vector.

The strategy is STATEFUL (accountant + noise PRNG key), so it
participates in the ``Federation`` checkpoint via
``state_dict``/``load_state_dict`` — resume is bitwise because the noise
key advances exactly once per round, sharing or not (the same budget
discipline the fold scheduler uses).
"""
from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.strategies.base import Payload, register
from repro.core.strategies.dml import DML
from repro.privacy.accountant import RDPAccountant
from repro.privacy.dp import DPSpec


@register
class DPDML(DML):
    """Dense DML with clipped + Gaussian-noised prediction payloads.

    ``dp_clip``: L2 bound on each client's flattened per-epoch payload.
    ``dp_noise_multiplier``: noise std in units of ``dp_clip``.
    ``dp_delta``: the δ at which ``epsilon()`` reports the guarantee.
    ``dp_seed``: seeds the noise PRNG chain (independent of the
    population's model/data keys).
    """
    name = "dp-dml"

    def __init__(self, kl_weight: float = 1.0, mutual_epochs: int = 1,
                 dp_clip: float = 1.0, dp_noise_multiplier: float = 1.0,
                 dp_delta: float = 1e-5, dp_seed: int = 0):
        super().__init__(kl_weight=kl_weight, mutual_epochs=mutual_epochs)
        if dp_clip <= 0:
            raise ValueError(f"dp_clip must be > 0, got {dp_clip}")
        if dp_noise_multiplier <= 0:
            raise ValueError("dp_noise_multiplier must be > 0, got "
                             f"{dp_noise_multiplier} (use DML for the "
                             "noiseless protocol)")
        self.dp_clip = float(dp_clip)
        self.dp_noise_multiplier = float(dp_noise_multiplier)
        self.dp_delta = float(dp_delta)
        self.accountant = RDPAccountant()
        self._noise_key = jax.random.PRNGKey(
            np.uint32(dp_seed ^ 0xD9E57A11))

    # -- protocol ----------------------------------------------------------
    def combine(self, pop, r: int, part: List[int], pm,
                payload: Payload) -> Dict[str, Any]:
        # the key advances EVERY round (shared or not) so a restored
        # session replays the identical noise stream — same discipline as
        # the fold budget
        self._noise_key, sub = jax.random.split(self._noise_key)
        keys = jax.random.split(sub, self.mutual_epochs)
        out = pop.mutual_phase(
            r, part, pm, payload, self.kl_weight, self.mutual_epochs,
            sparse_k=0,
            dp=DPSpec(clip=self.dp_clip,
                      noise_multiplier=self.dp_noise_multiplier,
                      keys=keys))
        if out.get("ran"):
            # one Gaussian release per mutual epoch per client: the
            # reported curve is the PER-CLIENT epsilon (each client's own
            # data only enters its own releases)
            self.accountant.step(self.dp_noise_multiplier,
                                 releases=self.mutual_epochs)
        payload.positions = int(out.get("positions", 0))
        out["epsilon"] = self.epsilon()
        return out

    def epsilon(self) -> float:
        """The session's (ε, dp_delta) guarantee so far, per client."""
        return self.accountant.epsilon(self.dp_delta)

    # -- checkpoint --------------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        key = self._noise_key
        if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
            key = jax.random.key_data(key)
        return {"accountant": self.accountant.state(),
                "noise_key": np.asarray(key).tolist(),
                "dp_clip": self.dp_clip,
                "dp_noise_multiplier": self.dp_noise_multiplier,
                "dp_delta": self.dp_delta}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        for knob in ("dp_clip", "dp_noise_multiplier", "dp_delta"):
            want, have = float(state[knob]), float(getattr(self, knob))
            if want != have:
                raise ValueError(
                    f"checkpoint {knob}={want} != session {knob}={have}; "
                    "the accountant's curve is only valid for the noise "
                    "schedule it recorded")
        self.accountant.load_state(state["accountant"])
        self._noise_key = jnp.asarray(np.asarray(state["noise_key"],
                                                 np.uint32))
