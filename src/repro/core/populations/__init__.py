"""Client populations for ``repro.api.Federation`` — who federates and
on what execution backend:

- :class:`VisionClients`  the paper's stacked VisionNet fleet (Algorithm 1;
                          single-device vmap or a ``clients`` mesh)
- :class:`HeteroClients`  architecture-heterogeneous clients via the
                          per-client model registry
- :class:`LMClients`      LLM-scale stacked clients over the
                          ``core.distributed`` fused step factories

``Population`` documents the capability surface strategies drive.
"""
from repro.core.populations.base import Population
from repro.core.populations.hetero import (HeteroClients,
                                           comm_bytes_per_round,
                                           make_lm_pool)
from repro.core.populations.lm import LMClients
from repro.core.populations.vision import VisionClients

__all__ = ["Population", "VisionClients", "HeteroClients", "LMClients",
           "comm_bytes_per_round", "make_lm_pool"]
