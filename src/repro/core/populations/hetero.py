"""Heterogeneous-client population — the paper's §I motivation
("different IoT devices ... might use different architectures") as a
``Federation`` population.

Each client declares its own model family through the per-client registry
(``models.get_client_model``): dense transformer, attention-free SSM,
fine-grained MoE, or the paper's VisionNet.  Weight averaging is undefined
across these clients — the pytrees do not even match — but prediction
sharing does not care: the ONLY tensor that ever crosses a client boundary
is the (K, N_pub, V) stack of public-set logits (dense DML) or its top-k
compression (SparseDML), so the population works for any mix of families
that agree on the prediction space V.

Per round each participant runs its local epochs as ONE jitted
``lax.scan`` program over its fixed-shape (T, B) batch plan (clients
cannot be vmapped together — their pytrees differ — but each client is
still one program per round), then the mutual phase descends Eq. 1
against the received predictions (``mutual.kl_to_received`` /
``mutual.sparse_kl_to_received``).

Weight strategies (``fedavg`` / ``async``) are accepted ONLY when every
client declares the same arch (identical pytrees — the degenerate case
where averaging is defined again); mixed fleets reject them at session
construction, which is the paper's point made executable.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stacking
from repro.core.async_fl import layer_schedule
from repro.core.fedavg import average_weights, weighted_average_weights
from repro.core.mutual import (kl_to_received, kl_to_robust_received,
                               sparse_kl_to_received, topk_predictions)
from repro.core.populations.base import Population, broadcast_mask_counts
from repro.privacy.dp import dp_noise_payload
from repro.data.federated import FoldScheduler, round_batch_indices
from repro.data.synthetic import make_token_stream
from repro.kernels import ops
from repro.models import ClientModel, get_client_model
from repro.optim import AdamWConfig, adamw_init, adamw_update


def comm_bytes_per_round(n_participants: int, n_pub: int, n_classes: int,
                         mutual_epochs: int,
                         bytes_per_el: int = 4) -> Dict[str, int]:
    """Cost-accounting dict for one heterogeneous DML round.

    Every mutual epoch each of the M participants ships its (N_pub, V)
    logits up and receives the (M, N_pub, V) broadcast down — the same
    up+down convention as the homogeneous engine, with bytes independent
    of any model's parameter count (the paper's bandwidth claim; weight
    averaging is not even defined here).
    """
    per_epoch = n_participants * n_pub * n_classes * bytes_per_el
    return {"per_epoch_up": per_epoch, "per_epoch_down": per_epoch,
            "round": mutual_epochs * 2 * per_epoch}


def make_lm_pool(n_seqs: int, seq_len: int, vocab: int, seed: int = 0,
                 n_domains: int = 4) -> Tuple[np.ndarray, np.ndarray]:
    """Token pool + domain labels for the fold schedule.

    Rows come from ``n_domains`` bigram rules; the domain id doubles as the
    stratification label so every fold mixes all domains (the IID setting).
    """
    per = -(-n_seqs // n_domains)
    parts = [make_token_stream(per, seq_len, vocab, seed=seed + d, domain=d)
             for d in range(n_domains)]
    data = np.concatenate(parts)[:n_seqs]
    labels = np.repeat(np.arange(n_domains), per)[:n_seqs]
    return data, labels.astype(np.int64)


class HeteroClients(Population):
    """Architecture-heterogeneous clients on a (data, labels) pool.

    ``data``: (N, ...) examples — token streams (N, S) for 'lm' clients,
    images (N, H, W, C) for 'vision' clients.  ``labels``: (N,) ints used
    for stratified folds (and as targets for 'vision' clients).
    """

    engine_name = "hetero"
    supported = frozenset({"dml", "sparse-dml", "fedavg", "async",
                           "dp-dml", "trimmed-dml", "median-dml"})
    log_participants_always = True
    _BYZ_MODES = ("label-flip", "sign-flip", "collude")

    def __init__(self, archs: Tuple[str, ...], data: np.ndarray,
                 labels: np.ndarray, rounds: int = 4,
                 local_epochs: int = 1, batch_size: int = 4,
                 public_batch: int = 4, lr: float = 3e-3, seed: int = 0,
                 mutual_updates_per_round: int = 1, reduced: bool = True,
                 kernel_impl: str = "auto", byzantine=None,
                 record_payloads: bool = False):
        self.archs = tuple(archs)
        # resolved once; the sparse mutual programs bake it into their jit
        # caches (the per-arch model forwards keep their own defaults)
        self.impl = ops.resolve_impl(kernel_impl)
        self.data = data
        self.labels = labels
        self.n_clients = len(self.archs)
        self.rounds = rounds
        self.local_epochs = local_epochs
        self.batch_size = batch_size
        self.seed = seed
        # one ClientModel per unique arch so duplicate-arch clients share
        # jit caches; one params/opt pytree per client
        self._models: Dict[str, ClientModel] = {
            a: get_client_model(a, reduced=reduced) for a in set(self.archs)}
        kinds = {m.kind for m in self._models.values()}
        if len(kinds) != 1:
            raise ValueError(f"clients mix modalities {sorted(kinds)}; a "
                             "federation needs one public-set modality")
        self.kind = kinds.pop()
        spaces = {m.n_classes for m in self._models.values()}
        if len(spaces) != 1:
            raise ValueError(f"clients disagree on the prediction space V "
                             f"({sorted(spaces)}); shared vocab required")
        self.n_classes = spaces.pop()
        self.byzantine = {int(c): m for c, m in (byzantine or {}).items()}
        for c, mode in self.byzantine.items():
            if not 0 <= c < self.n_clients:
                raise ValueError(
                    f"byzantine client {c} out of range (K={self.n_clients})")
            if mode not in self._BYZ_MODES:
                raise ValueError(
                    f"unknown byzantine mode {mode!r} for client {c}; "
                    f"HeteroClients supports {self._BYZ_MODES}")
            if mode == "label-flip" and self.kind == "lm":
                raise ValueError(
                    "label-flip is undefined for 'lm' clients (the private "
                    "loss is next-token CE on the inputs; labels are only "
                    "fold-stratification ids) — use sign-flip or collude")
        self.record_payloads = bool(record_payloads)
        self.payload_log: List[dict] = []
        self.opt_cfg = AdamWConfig(
            lr=lr, warmup=2,
            total_steps=max(rounds * (local_epochs
                                      + mutual_updates_per_round), 1))
        self.base_key = jax.random.PRNGKey(seed)
        keys = jax.random.split(jax.random.fold_in(self.base_key, 0xC11E47),
                                self.n_clients)
        self.client_params = [self._models[a].init(k)
                              for a, k in zip(self.archs, keys)]
        self.client_opts = [adamw_init(p) for p in self.client_params]
        self.n_params = [sum(np.size(x) for x in jax.tree.leaves(p))
                         for p in self.client_params]
        # Algorithm-1 fold discipline; the init fold (the homogeneous
        # engine's global-model fold — there is no global model here)
        # becomes a common held-out eval fold
        self.folds = FoldScheduler(labels, self.n_clients, rounds,
                                   seed=seed)
        min_fold = len(labels) // self.folds.n_folds
        self._pub_n = max(1, min(public_batch, min_fold))
        self._local_T = local_epochs * max(1, min_fold // batch_size)
        self.eval_fold = self.folds.pop()[:max(self._pub_n, 1)]
        self._progs: Dict = {}
        self._plan_seed = seed * 100_003 + 29
        self._last_local_losses: List[float] = [0.0] * self.n_clients

    def validate_strategy(self, strategy) -> None:
        super().validate_strategy(strategy)
        if strategy.name in ("fedavg", "async") and \
                len(set(self.archs)) > 1:
            raise ValueError(
                f"strategy {strategy.name!r} shares weights, which is "
                f"undefined across heterogeneous clients (archs "
                f"{sorted(set(self.archs))} have different pytrees).  Use "
                "prediction sharing (dml / sparse-dml), or a fleet of one "
                "arch.")
        if strategy.name == "async" and self.kind != "lm":
            raise ValueError(
                "the async shallow/deep schedule on this population uses "
                "the transformer layer split; non-'lm' fleets "
                f"(kind={self.kind!r}) should use the VisionClients "
                "population for AsyncWeights")

    # -- per-arch jitted programs -----------------------------------------
    # kl-INDEPENDENT programs (local scan, sharing, eval) cache per arch;
    # only the Eq.-1 descent closes over kl_weight (and k for sparse) and
    # caches per (arch, kl_weight[, k]) — duplicate-arch clients and
    # different strategies share every program they legally can.
    def _prog(self, arch: str) -> Dict:
        if arch in self._progs:
            return self._progs[arch]
        cm = self._models[arch]
        opt_cfg = self.opt_cfg

        @jax.jit
        def local_scan(params, opt, inputs, labs, keys):
            """One client's whole local phase: scan over its (T, B) plan."""
            def body(carry, xs):
                p, o = carry
                inp, la, k = xs
                loss, grads = jax.value_and_grad(
                    lambda q: cm.private_loss(q, inp, la, k))(p)
                p2, o2, _ = adamw_update(p, grads, o, opt_cfg)
                return (p2, o2), loss
            (params, opt), losses = jax.lax.scan(body, (params, opt),
                                                 (inputs, labs, keys))
            return params, opt, jnp.mean(losses)

        share = jax.jit(cm.share_logits)
        eval_ce = jax.jit(
            lambda p, x, y: cm.public_ce_and_logits(p, x, y, None)[0])
        self._progs[arch] = {"local": local_scan, "share": share,
                             "eval_ce": eval_ce}
        return self._progs[arch]

    def _mutual_prog(self, arch: str, kl_weight: float):
        cache_key = (arch, kl_weight)
        if cache_key in self._progs:
            return self._progs[cache_key]
        cm = self._models[arch]
        opt_cfg = self.opt_cfg
        kl_w = kl_weight

        @jax.jit
        def mutual_step(params, opt, inputs, labs, others_logits, key):
            """Eq. 1 with the received logits fixed (one mutual epoch)."""
            def loss_fn(p):
                ce, live = cm.public_ce_and_logits(p, inputs, labs, key)
                kl = jnp.mean(kl_to_received(live, others_logits))
                return ce + kl_w * kl, (ce, kl)
            (_, (ce, kl)), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            params, opt, _ = adamw_update(params, grads, opt, opt_cfg)
            return params, opt, ce, kl

        self._progs[cache_key] = mutual_step
        return mutual_step

    def _robust_prog(self, arch: str, kl_weight: float, mode: str,
                     trim: int):
        cache_key = (arch, kl_weight, "robust", mode, trim)
        if cache_key in self._progs:
            return self._progs[cache_key]
        cm = self._models[arch]
        opt_cfg = self.opt_cfg
        kl_w = kl_weight

        @jax.jit
        def robust_step(params, opt, inputs, labs, others_logits, key):
            """Robust Eq. 1: KL to the trimmed/median consensus of the
            received logits instead of the mean of per-sender KLs."""
            def loss_fn(p):
                ce, live = cm.public_ce_and_logits(p, inputs, labs, key)
                kl = jnp.mean(kl_to_robust_received(live, others_logits,
                                                    mode, trim))
                return ce + kl_w * kl, (ce, kl)
            (_, (ce, kl)), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            params, opt, _ = adamw_update(params, grads, opt, opt_cfg)
            return params, opt, ce, kl

        self._progs[cache_key] = robust_step
        return robust_step

    def _sparse_prog(self, arch: str, kl_weight: float, k: int) -> Dict:
        """Top-k variants: publish (indices, log-probs) of the k most
        likely classes; descend Eq. 1 against the received sparse sets."""
        cache_key = (arch, kl_weight, "sparse", k, self.impl)
        if cache_key in self._progs:
            return self._progs[cache_key]
        cm = self._models[arch]
        opt_cfg = self.opt_cfg
        kl_w = kl_weight
        impl = self.impl

        @jax.jit
        def share_topk(params, inputs):
            return topk_predictions(cm.share_logits(params, inputs), k)

        @jax.jit
        def mutual_sparse(params, opt, inputs, labs, idx, logp, key):
            def loss_fn(p):
                ce, live = cm.public_ce_and_logits(p, inputs, labs, key)
                kl = jnp.mean(sparse_kl_to_received(live, idx, logp,
                                                    impl=impl))
                return ce + kl_w * kl, (ce, kl)
            (_, (ce, kl)), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            params, opt, _ = adamw_update(params, grads, opt, opt_cfg)
            return params, opt, ce, kl

        self._progs[cache_key] = {"share_topk": share_topk,
                                  "mutual_sparse": mutual_sparse}
        return self._progs[cache_key]

    # -- helpers ----------------------------------------------------------
    def _round_key(self, r: int) -> jax.Array:
        return jax.random.fold_in(self.base_key, r)

    def _gather(self, idx: np.ndarray):
        return jnp.asarray(self.data[idx]), jnp.asarray(self.labels[idx])

    @property
    def bytes_per_position(self) -> int:
        return self.n_classes * 4

    @property
    def params_per_client(self) -> int:
        return self.n_params[0]

    # -- strategy capabilities --------------------------------------------
    def local_phase(self, r: int, part: List[int], pm) -> List[float]:
        K = self.n_clients
        key_r = self._round_key(r)
        self._plan_seed += 1
        # K folds popped in Algorithm-1 order regardless of participation
        # (the fold budget is part of the protocol); the absentees' folds
        # go unused this round
        folds = [self.folds.pop() for _ in range(K)]
        local_losses = [0.0] * K
        for c in part:
            idx, _ = round_batch_indices([folds[c]], self.local_epochs,
                                         self.batch_size,
                                         seed=self._plan_seed * K + c)
            idx = idx[0, :self._local_T]            # fixed T: stable jit cache
            if idx.shape[0] == 0:
                continue
            inputs, labs = self._gather(idx)
            if self.byzantine.get(c) == "label-flip":
                labs = (labs + 1) % self.n_classes
            keys = jax.random.split(jax.random.fold_in(key_r, 100 + c),
                                    idx.shape[0])
            prog = self._prog(self.archs[c])
            self.client_params[c], self.client_opts[c], loss = prog["local"](
                self.client_params[c], self.client_opts[c], inputs, labs,
                keys)
            local_losses[c] = float(loss)
        self._last_local_losses = local_losses
        return local_losses

    def public_payload(self, r: int):
        # the rotating public fold, truncated to the public-batch budget
        return self.folds.pop()[:self._pub_n]

    def weights_payload(self, r: int):
        return self.folds.pop()[:self._pub_n]

    def _poison_stack(self, stack: np.ndarray, part: List[int],
                      pub_labs) -> np.ndarray:
        """Apply payload Byzantine modes to the senders' rows of the
        (M, N_pub, V) logit stack — what they actually put on the wire.
        Their own receipts stay honest; the attack is on what they SEND."""
        if not self.byzantine:
            return stack
        labs = np.asarray(pub_labs)
        for s, c in enumerate(part):
            mode = self.byzantine.get(c)
            if mode == "sign-flip":
                stack[s] = -stack[s]
            elif mode == "collude":
                wrong = (labs + 1) % self.n_classes
                oh = np.zeros_like(stack[s])
                oh[np.arange(len(labs)), wrong] = 8.0
                stack[s] = oh
        return stack

    def mutual_phase(self, r, part, pm, payload, kl_weight, mutual_epochs,
                     sparse_k: int = 0, dp=None, robust=None) -> dict:
        K = self.n_clients
        pub = payload.data
        pub_inputs, pub_labs = self._gather(pub)
        key_r = self._round_key(r)
        public_ce = [0.0] * K
        kl_losses = [0.0] * K
        out = {"ran": False, "positions": 0, "public_ce": public_ce,
               "kl_loss": kl_losses}
        if sparse_k and (dp is not None or robust is not None):
            raise ValueError("sparse payloads compose with neither the DP "
                             "release nor the robust combiners")
        if mutual_epochs <= 0 or len(part) < 2:
            return out
        n_pub = None
        for e in range(mutual_epochs):
            # every participant publishes; ONLY these tensors cross
            # client boundaries
            if sparse_k:
                shared = [tuple(np.asarray(t) for t in self._sparse_prog(
                    self.archs[c], kl_weight, sparse_k)["share_topk"](
                        self.client_params[c], pub_inputs)) for c in part]
                idx_stack = np.stack([s[0] for s in shared])  # (M,N_pub,k)
                logp_stack = np.stack([s[1] for s in shared])
                n_pub = idx_stack.shape[1]
            else:
                shared = [np.asarray(self._prog(self.archs[c])["share"](
                    self.client_params[c], pub_inputs)) for c in part]
                stack = np.stack(shared)            # (M, N_pub, V)
                stack = self._poison_stack(stack, part, pub_labs)
                if dp is not None:
                    # the whole stacked payload noised at once: one
                    # release per sender (leading-axis slices), one key
                    # per epoch
                    stack = np.asarray(dp_noise_payload(
                        jnp.asarray(stack), dp.clip, dp.noise_multiplier,
                        dp.keys[e]))
                if self.record_payloads:
                    self.payload_log.append(
                        {"round": r, "epoch": e, "part": list(part),
                         "public": np.asarray(pub), "payloads": stack.copy()})
                n_pub = stack.shape[1]
            for s, c in enumerate(part):
                k = jax.random.fold_in(key_r, 1000 + e * K + c)
                if sparse_k:
                    others_idx = jnp.asarray(np.delete(idx_stack, s, axis=0))
                    others_logp = jnp.asarray(np.delete(logp_stack, s,
                                                        axis=0))
                    prog = self._sparse_prog(self.archs[c], kl_weight,
                                             sparse_k)
                    (self.client_params[c], self.client_opts[c],
                     ce, kl) = prog["mutual_sparse"](
                        self.client_params[c], self.client_opts[c],
                        pub_inputs, pub_labs, others_idx, others_logp, k)
                else:
                    others = jnp.asarray(np.delete(stack, s, axis=0))
                    if robust is not None:
                        step = self._robust_prog(self.archs[c], kl_weight,
                                                 robust[0], int(robust[1]))
                    else:
                        step = self._mutual_prog(self.archs[c], kl_weight)
                    (self.client_params[c], self.client_opts[c],
                     ce, kl) = step(
                        self.client_params[c], self.client_opts[c],
                        pub_inputs, pub_labs, others, k)
                public_ce[c] = float(ce)
                kl_losses[c] = float(kl)
        return {"ran": True, "positions": n_pub, "public_ce": public_ce,
                "kl_loss": kl_losses}

    # -- weight strategies: the identical-arch degenerate case -------------
    def _stacked(self):
        return stacking.stack_params(self.client_params)

    def _unstack_into(self, stacked) -> None:
        self.client_params = stacking.unstack_params(stacked,
                                                     self.n_clients)

    def fedavg_combine(self, part: List[int], pm) -> None:
        stacked = self._stacked()
        if len(part) == self.n_clients:
            stacked = average_weights(stacked)
        else:
            avg = weighted_average_weights(stacked, jnp.asarray(pm))
            stacked = stacking.client_lerp(stacked, avg, pm)
        self._unstack_into(stacked)

    def async_combine(self, r, part, pm, delta, min_round, pub) -> str:
        from repro.core.distributed import async_sync
        layer = layer_schedule(r, delta, min_round)
        stacked = self._stacked()
        # weighting metric: inverse local loss (the engine has no
        # per-client held-out accuracy for LM clients), masked so
        # absentees contribute nothing and receive nothing back
        scores = np.asarray(
            [1.0 / (1.0 + max(l, 0.0)) for l in self._last_local_losses],
            np.float32) * pm
        synced = async_sync(stacked, jnp.asarray(scores),
                            self._shallow_mask(stacked), r, delta, min_round)
        if len(part) < self.n_clients:
            synced = stacking.client_lerp(stacked, synced, pm)
        self._unstack_into(synced)
        return layer

    def _shallow_mask(self, stacked):
        if not hasattr(self, "_shallow_mask_cache"):
            from repro.core.distributed import transformer_shallow_mask
            cfg = self._models[self.archs[0]].cfg
            self._shallow_mask_cache = transformer_shallow_mask(cfg, stacked)
        return self._shallow_mask_cache

    def async_param_counts(self):
        stacked = self._stacked()
        return broadcast_mask_counts(stacked, self._shallow_mask(stacked),
                                     self.n_clients)

    # -- eval -------------------------------------------------------------
    def evaluate(self, history, split=None):
        """Per-client model loss on the common held-out fold (comparable
        across families — it is the same public-style CE every client
        optimises in Eq. 1)."""
        if split is not None:
            raise ValueError(
                "the hetero population evaluates on its held-out common "
                "fold; call evaluate() / evaluate(split=None)")
        inputs, labs = self._gather(self.eval_fold)
        history.client_eval_loss = [
            float(self._prog(a)["eval_ce"](p, inputs, labs))
            for a, p in zip(self.archs, self.client_params)]
        return history

    # -- checkpoint/resume ------------------------------------------------
    def state_dict(self) -> dict:
        return {"clients": [{"params": p, "opt": o} for p, o in
                            zip(self.client_params, self.client_opts)]}

    def meta_dict(self) -> dict:
        return {
            "engine": self.engine_name,
            "archs": list(self.archs),
            "n_rounds": self.rounds,
            "pool_n": len(self.labels),
            "plan_seed": self._plan_seed,
            "scheduler": self.folds.state(),
        }

    def check_meta(self, meta: dict) -> None:
        if meta.get("archs") != list(self.archs):
            raise ValueError(f"checkpoint archs {meta.get('archs')} != "
                             f"config archs {list(self.archs)}")
        # the fold PARTITION is deterministic in (labels, K, rounds, seed):
        # a different round schedule or pool silently re-partitions the
        # data, so the restored cursor would index folds the checkpointed
        # run never saw — refuse instead of resuming on the wrong folds
        if meta.get("n_rounds", self.rounds) != self.rounds or \
                meta.get("pool_n", len(self.labels)) != len(self.labels):
            raise ValueError(
                f"checkpoint schedule (rounds={meta.get('n_rounds')}, "
                f"pool={meta.get('pool_n')}) != config "
                f"(rounds={self.rounds}, pool={len(self.labels)}); "
                "resume needs the same fold partition — save with the full "
                "round budget and stop early via run(until=...)")

    def load_state_dict(self, state: dict, meta: dict) -> None:
        self.client_params = [c["params"] for c in state["clients"]]
        self.client_opts = [c["opt"] for c in state["clients"]]
        self._plan_seed = int(meta["plan_seed"])
        self.folds.load_state(meta["scheduler"])
