"""Shared surface of the client populations a ``Federation`` can run.

A population owns everything *model-side* of the protocol: the client
parameters/optimizers, the data + fold schedule, the jitted programs,
and the execution backend (single-device vmap vs a ``clients`` mesh).
Strategies (``core.strategies``) drive it through the capability methods
below; a population advertises which strategies it can execute via
``supported`` and may veto specific pairings in ``validate_strategy``
(e.g. weight averaging across heterogeneous pytrees).
"""
from __future__ import annotations

from typing import List

import numpy as np


class Population:
    """Capability/constants surface; concrete populations override."""

    engine_name: str = "population"          # checkpoint meta "engine" tag
    supported: frozenset = frozenset()
    fused_dml: bool = False                  # local+mutual in one program?
    log_participants_always: bool = False    # hetero convention: log the
    #                                          list even at M == K
    bytes_per_position: int = 4              # payload bytes per shared
    #                                          prediction position
    n_clients: int = 0
    rounds: int = 0
    seed: int = 0

    # -- session plumbing --------------------------------------------------
    def validate_strategy(self, strategy) -> None:
        if strategy.name not in self.supported:
            raise ValueError(
                f"{type(self).__name__} does not support strategy "
                f"{strategy.name!r} (supported: {sorted(self.supported)})")

    def begin_round(self, r: int) -> None:
        """Called by the session before each round (dispatch-log phase)."""

    def part_mask(self, part: List[int]) -> np.ndarray:
        mask = np.zeros((self.n_clients,), np.float32)
        mask[part] = 1.0
        return mask

    # -- capabilities (strategy-facing; optional per population) ----------
    def local_phase(self, r: int, part: List[int], pm) -> List[float]:
        raise NotImplementedError

    def public_payload(self, r: int):
        """Pop/materialise the round's shared public fold."""
        raise NotImplementedError

    def weights_payload(self, r: int):
        """Weight strategies keep the Algorithm-1 fold budget: the shared
        fold is still popped every round (FedAvg discards it; async trains
        the global model on it), so checkpoints stay schedule-compatible
        across strategies."""
        return None

    def mutual_phase(self, r, part, pm, payload, kl_weight, mutual_epochs,
                     sparse_k: int = 0, dp=None, robust=None) -> dict:
        """``dp``: a ``privacy.dp.DPSpec`` — clip + Gaussian-noise each
        client's shared predictions before they cross the boundary
        (DP-DML).  ``robust``: ``(mode, trim)`` — replace the Eq.-2 mean
        with a trimmed-mean/median consensus target (Byzantine-robust
        variants).  Populations that list the corresponding strategies in
        ``supported`` must honour both."""
        raise NotImplementedError

    def fedavg_combine(self, part: List[int], pm) -> None:
        raise NotImplementedError

    def async_combine(self, r, part, pm, delta, min_round, pub) -> str:
        raise NotImplementedError

    def async_param_counts(self):
        raise NotImplementedError

    @property
    def params_per_client(self) -> int:
        raise NotImplementedError

    # -- evaluation / checkpoint ------------------------------------------
    def evaluate(self, history, split=None):
        raise NotImplementedError

    def state_dict(self) -> dict:
        raise NotImplementedError

    def meta_dict(self) -> dict:
        raise NotImplementedError

    def check_meta(self, meta: dict) -> None:
        """Refuse checkpoints whose schedule/population don't match."""

    def load_state_dict(self, state: dict, meta: dict) -> None:
        raise NotImplementedError


def broadcast_mask_counts(stacked_params, mask_tree, n_clients: int):
    """(n_in_mask, n_outside_mask) per client for broadcast-shaped float
    mask trees (e.g. ``distributed.transformer_shallow_mask``, whose
    leaves are (1, ...) selectors broadcast against the param leaves)."""
    n_in = n_out = 0.0
    import jax
    for p, m in zip(jax.tree.leaves(stacked_params),
                    jax.tree.leaves(mask_tree)):
        m = np.broadcast_to(np.asarray(m, np.float32), p.shape)
        n_in += float(m.sum())
        n_out += float((1.0 - m).sum())
    return int(round(n_in / n_clients)), int(round(n_out / n_clients))
