"""LLM-scale stacked client population — the ``core.distributed`` step
factories behind the ``Federation`` session layer.

K same-arch clients live as a leading axis on every param/opt leaf
(``core.stacking``); one round is ONE fused jitted program:

  - dml / sparse-dml: ``distributed.make_dml_train_step`` — private CE +
    Eq. 1 on the round's public batch in a single update (``fused_dml``:
    the strategy's local phase and combine are one program here).  With a
    ``clients`` mesh, ``make_sharded_dml_step`` runs the same semantics
    device-sharded with ONE all-gather of public logits per round.
  - fedavg / async: ``make_local_train_step`` for the local phase, then
    ``fedavg_sync`` / ``async_sync`` on the stacked axis.

Private data is per-client synthetic bigram streams (one domain per
client — non-IID); the public batch is fresh every round ("dynamically
changing test dataset", paper §III.A).
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed as D
from repro.core import stacking
from repro.core.async_fl import layer_schedule
from repro.core.populations.base import Population, broadcast_mask_counts
from repro.data.synthetic import make_token_stream
from repro.kernels import ops
from repro.models import transformer as tfm
from repro.optim import AdamWConfig


class LMClients(Population):
    """K stacked same-arch LM clients on synthetic domain streams."""

    engine_name = "lm"
    supported = frozenset({"dml", "sparse-dml", "fedavg", "async"})
    fused_dml = True
    log_participants_always = True

    def __init__(self, cfg, n_clients: int = 2, rounds: int = 20,
                 batch: int = 4, seq: int = 64, lr: float = 1e-3,
                 seed: int = 0, mesh=None, kernel_impl: str = "auto"):
        self.cfg = cfg
        self.n_clients = n_clients
        self.rounds = rounds
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.mesh = mesh
        # kernel impl policy is resolved ONCE here ("auto" -> pallas on TPU,
        # ref elsewhere, REPRO_KERNEL_IMPL overrides) and threaded through
        # every step factory as a plain argument — the jitted hot path never
        # reads the ambient ops.get_impl() state
        self.impl = ops.resolve_impl(kernel_impl)
        self.opt_cfg = AdamWConfig(lr=lr, warmup=5, total_steps=rounds)
        key = jax.random.PRNGKey(seed)
        self.client_params = D.stacked_init(key, cfg, n_clients)
        self.client_opts = D.stacked_adamw_init(self.client_params)
        self._steps = {}
        self._last_metrics = {}

    def validate_strategy(self, strategy) -> None:
        super().validate_strategy(strategy)
        if getattr(strategy, "mutual_epochs", 1) != 1:
            raise ValueError(
                "the LM population fuses the whole round into one update "
                "program; mutual_epochs must be 1")
        if self.mesh is not None and strategy.name != "dml":
            raise ValueError(
                "mesh-sharded LM rounds support the dense dml strategy "
                f"only (make_sharded_dml_step), got {strategy.name!r}")

    # -- data -------------------------------------------------------------
    def _private_batch(self, r: int):
        """(K, B, S) tokens — each client has its own bigram domain."""
        return jnp.stack([
            jnp.asarray(make_token_stream(
                self.batch, self.seq + 1, self.cfg.vocab_size,
                seed=1000 * r + self.seed, domain=d)[:, :self.seq])
            for d in range(self.n_clients)])

    def _public_batch(self, r: int):
        """(B_pub, S) fresh public tokens from an unseen domain."""
        return jnp.asarray(make_token_stream(
            max(1, self.batch // 2), self.seq + 1, self.cfg.vocab_size,
            seed=1000 * (10_000 + r) + self.seed,
            domain=self.n_clients)[:, :self.seq])

    def _prefix(self, r: int, batch: int):
        """(B, P, pd) conditioning embeddings for modality-frontend archs
        (``cfg.prefix_tokens`` > 0); None otherwise."""
        if not self.cfg.prefix_tokens:
            return None
        rng = np.random.default_rng(r)
        return jnp.asarray(rng.normal(
            0, 1, (batch, self.cfg.prefix_tokens, self.cfg.prefix_dim)
        ).astype(np.float32))

    def _private_prefix(self, r: int):
        p = self._prefix(r, self.batch)
        if p is None:
            return None
        return jnp.broadcast_to(p[None], (self.n_clients,) + p.shape)

    # -- cached jitted steps ----------------------------------------------
    def _dml_step(self, kl_weight: float, sparse_k: int):
        key = ("dml", kl_weight, sparse_k, self.mesh is not None, self.impl)
        if key not in self._steps:
            if self.mesh is not None:
                self._steps[key] = jax.jit(D.make_sharded_dml_step(
                    self.cfg, self.opt_cfg, self.mesh, self.n_clients,
                    kl_weight=kl_weight, impl=self.impl))
            else:
                self._steps[key] = jax.jit(D.make_dml_train_step(
                    self.cfg, self.opt_cfg, kl_weight=kl_weight,
                    sparse_k=sparse_k, impl=self.impl))
        return self._steps[key]

    def _local_step(self):
        key = ("local", self.impl)
        if key not in self._steps:
            self._steps[key] = jax.jit(D.make_local_train_step(
                self.cfg, self.opt_cfg, impl=self.impl))
        return self._steps[key]

    # -- strategy capabilities --------------------------------------------
    def local_phase(self, r: int, part: List[int], pm) -> List[float]:
        part_mask = jnp.asarray(pm) if len(part) < self.n_clients else None
        tokens = self._private_batch(r)
        self.client_params, self.client_opts, m = self._local_step()(
            self.client_params, self.client_opts, tokens,
            self._private_prefix(r), part_mask)
        self._last_metrics = m
        return [float(x) * w for x, w in zip(np.asarray(m["ce"]), pm)]

    def public_payload(self, r: int):
        return self._public_batch(r)

    def weights_payload(self, r: int):
        return None                      # no fold schedule to discipline

    def mutual_phase(self, r, part, pm, payload, kl_weight, mutual_epochs,
                     sparse_k: int = 0) -> dict:
        pub = payload.data
        if len(part) < 2:
            # nothing to share with: participants train locally only —
            # the same skip every other population applies when M < 2
            losses = self.local_phase(r, part, pm)
            return {"ran": False, "positions": 0, "client_loss": losses,
                    "kl_loss": [0.0] * self.n_clients}
        if sparse_k and len(part) < self.n_clients:
            raise ValueError("sparse top-k sharing + partial participation "
                             "is not supported by the fused LM step")
        part_mask = jnp.asarray(pm) if len(part) < self.n_clients else None
        tokens = self._private_batch(r)
        step = self._dml_step(kl_weight, sparse_k)
        if self.mesh is not None:
            self.client_params, self.client_opts, m = step(
                self.client_params, self.client_opts, tokens, pub,
                part_mask=part_mask)
        else:
            self.client_params, self.client_opts, m = step(
                self.client_params, self.client_opts, tokens, pub,
                prefix=self._private_prefix(r),
                public_prefix=self._prefix(10_000 + r,
                                           int(pub.shape[0])),
                part_mask=part_mask)
        self._last_metrics = m
        return {"ran": len(part) >= 2,
                "positions": int(pub.shape[0]) * int(pub.shape[1]),
                "client_loss": [float(x) for x in
                                np.asarray(m["private_loss"])],
                "public_ce": [float(x) for x in np.asarray(m["public_ce"])],
                "kl_loss": [float(x) for x in np.asarray(m["kld_avg"])]}

    def fedavg_combine(self, part: List[int], pm) -> None:
        full = len(part) == self.n_clients
        self.client_params = D.fedavg_sync(
            self.client_params, None if full else jnp.asarray(pm))

    def async_combine(self, r, part, pm, delta, min_round, pub) -> str:
        layer = layer_schedule(r, delta, min_round)
        ce = np.asarray(self._last_metrics["ce"], np.float32)
        # weighting metric: inverse local loss, masked so absentees
        # contribute no weight and receive nothing back
        scores = (1.0 / (1.0 + np.maximum(ce, 0.0))) * pm
        synced = D.async_sync(self.client_params, jnp.asarray(scores),
                              self._shallow_mask(), r, delta, min_round)
        if len(part) < self.n_clients:
            synced = stacking.client_lerp(self.client_params, synced, pm)
        self.client_params = synced
        return layer

    def _shallow_mask(self):
        if not hasattr(self, "_shallow_mask_cache"):
            self._shallow_mask_cache = D.transformer_shallow_mask(
                self.cfg, self.client_params)
        return self._shallow_mask_cache

    def async_param_counts(self):
        return broadcast_mask_counts(self.client_params,
                                     self._shallow_mask(), self.n_clients)

    @property
    def bytes_per_position(self) -> int:
        return self.cfg.vocab_size * 4

    @property
    def params_per_client(self) -> int:
        total = sum(x.size for x in jax.tree.leaves(self.client_params))
        return int(total // self.n_clients)

    # -- eval / checkpoint -------------------------------------------------
    def evaluate(self, history, split=None):
        """Per-client CE on a fresh shared eval batch (domain K, never a
        training domain)."""
        if split is not None:
            raise ValueError(
                "the LM population evaluates on a fresh held-out synthetic "
                "batch; call evaluate() / evaluate(split=None)")
        toks = jnp.asarray(make_token_stream(
            self.batch, self.seq + 1, self.cfg.vocab_size,
            seed=777_000 + self.seed, domain=self.n_clients)[:, :self.seq])
        if "eval" not in self._steps:
            self._steps["eval"] = jax.jit(jax.vmap(
                lambda p, t, pe: tfm.loss_fn(p, self.cfg, t, pe,
                                             impl=self.impl)[0],
                in_axes=(0, None, None)))
        losses = self._steps["eval"](self.client_params, toks,
                                     self._prefix(777_000, self.batch))
        history.client_eval_loss = [float(x) for x in np.asarray(losses)]
        return history

    def state_dict(self) -> dict:
        return {"client_params": self.client_params,
                "client_opts": self.client_opts}

    def meta_dict(self) -> dict:
        return {"engine": self.engine_name, "arch": self.cfg.name,
                "n_clients": self.n_clients, "n_rounds": self.rounds}

    def check_meta(self, meta: dict) -> None:
        if meta.get("arch") != self.cfg.name or \
                meta.get("n_clients") != self.n_clients:
            raise ValueError(
                f"checkpoint (arch={meta.get('arch')}, "
                f"K={meta.get('n_clients')}) != config "
                f"(arch={self.cfg.name}, K={self.n_clients})")

    def load_state_dict(self, state: dict, meta: dict) -> None:
        self.client_params = state["client_params"]
        self.client_opts = state["client_opts"]
