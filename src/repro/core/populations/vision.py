"""Stacked-VisionNet client population — the paper's Algorithm-1 case
study as a ``Federation`` population.

Clients are a *stacked* pytree (leading axis K — ``core.stacking``, the
same client-axis layout the mesh-scale path shards over pods) and a full
round executes as a handful of jitted programs instead of O(K · batches)
Python-dispatched calls:

  _local_scan     vmap over clients of lax.scan over the fixed-shape
                  (K, T, B) batch plan from ``data.federated``
  _mutual_scan    all mutual epochs fused: dropout-free share + Eq.-1
                  descent for all K clients (``mutual.bernoulli_mutual_terms_vs``)
  _predict_stacked  vmapped inference — sharing, scores, and eval

With a ``clients`` mesh (``VisionClients(..., mesh=...)``) the same two
training programs run inside ``sharding.shard_map`` over the client axis:
each device owns whole clients (round-robin spill for K > n_devices via
``stacking.client_layout``), local training is collective-free, and the
mutual phase's ONLY cross-device traffic is one all-gather of the public-
fold predictions per mutual epoch — exactly the bytes the strategy's
``comm_bytes`` simulates.  Results are bitwise-identical to the unsharded
engine (tests/test_multidevice.py holds this for all 3 methods).

The population executes the ``dml`` / ``fedavg`` / ``async`` strategies;
``sparse-dml`` is rejected — the VisionNet head shares Bernoulli
probabilities (one float per example), which have no top-k structure to
sparsify.
"""
from __future__ import annotations

import functools
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import sharding
from repro.configs.visionnet import VisionNetConfig
from repro.core import async_fl, fedavg, stacking
from repro.core.mutual import (_pair_mask, bernoulli_kl_to_target,
                               bernoulli_mutual_terms_vs,
                               robust_bernoulli_target)
from repro.core.populations.base import Population
from repro.privacy.dp import dp_probs_payload
from repro.data.federated import (FoldScheduler, NonIIDScheduler,
                                  round_batch_indices)
from repro.models.visionnet import (bce_loss, init_visionnet,
                                    shallow_deep_split, visionnet_forward)
from repro.optim import SGDConfig, sgd_init, sgd_update

# ---------------------------------------------------------------------------
# jitted programs — each one covers ALL K clients in a single dispatch


def _masked_lerp(old, new, w):
    """Apply ``new`` only where the step is real (w=1); padding keeps old."""
    return jax.tree.map(lambda a, b: w * b + (1 - w) * a, old, new)


def _local_scan_impl(stacked_params, stacked_opt, images, labels, masks,
                     keys, vn_cfg: VisionNetConfig, sgd_cfg: SGDConfig,
                     conv_impl: str = "fused"):
    """Body of ``_local_scan`` — also the per-device shard_map body of
    ``_sharded_local_scan`` (per-client work is embarrassingly parallel, so
    the sharded engine runs this code unchanged on each device's slice).

    K > 1 runs in canonical width-2 client chunks
    (``stacking.chunked_client_map``) so the per-client arithmetic is
    bit-identical no matter how many clients this program instance holds;
    K == 1 (the global model) keeps the plain single-client vmap.
    """

    def one_client(params, opt, imgs, labs, w, ks):
        def body(carry, xs):
            p, o = carry
            im, la, wi, k = xs

            def loss_fn(q):
                probs = visionnet_forward(q, vn_cfg, im, train=True,
                                          dropout_key=k,
                                          conv_impl=conv_impl)
                return bce_loss(probs, la)

            loss, grads = jax.value_and_grad(loss_fn)(p)
            p2, o2, _ = sgd_update(p, grads, o, sgd_cfg)
            p2 = _masked_lerp(p, p2, wi)
            o2 = {"vel": _masked_lerp(o["vel"], o2["vel"], wi),
                  "step": o["step"] + wi.astype(jnp.int32)}
            return (p2, o2), loss * wi

        (params, opt), losses = jax.lax.scan(body, (params, opt),
                                             (imgs, labs, w, ks))
        return params, opt, jnp.sum(losses) / jnp.maximum(jnp.sum(w), 1.0)

    args = (stacked_params, stacked_opt, images, labels, masks, keys)
    K = jax.tree.leaves(stacked_params)[0].shape[0]
    if K == 1:
        return jax.vmap(one_client)(*args)
    return stacking.chunked_client_map(
        lambda a, _c: jax.vmap(one_client)(*a), args, K)


@functools.partial(jax.jit, static_argnames=("vn_cfg", "sgd_cfg",
                                             "conv_impl"))
def _local_scan(stacked_params, stacked_opt, images, labels, masks, keys,
                vn_cfg: VisionNetConfig, sgd_cfg: SGDConfig,
                conv_impl: str = "fused"):
    """Local epochs for all clients: vmap(client) of scan(batch plan).

    images (K,T,B,H,W,C) · labels (K,T,B) · masks (K,T) · keys (K,T,2).
    Returns (stacked_params, stacked_opt, mean BCE per client (K,)).
    """
    return _local_scan_impl(stacked_params, stacked_opt, images, labels,
                            masks, keys, vn_cfg, sgd_cfg, conv_impl)


@functools.lru_cache(maxsize=None)
def _sharded_local_program(mesh, n_clients: int, vn_cfg: VisionNetConfig,
                           sgd_cfg: SGDConfig, conv_impl: str):
    body = functools.partial(_local_scan_impl, vn_cfg=vn_cfg,
                             sgd_cfg=sgd_cfg, conv_impl=conv_impl)
    spec = stacking.client_spec()
    return jax.jit(sharding.shard_map(body, mesh, in_specs=(spec,) * 6,
                                      out_specs=(spec, spec, spec)))


def _sharded_local_scan(stacked_params, stacked_opt, images, labels, masks,
                        keys, mesh, n_clients: int,
                        vn_cfg: VisionNetConfig, sgd_cfg: SGDConfig,
                        conv_impl: str = "fused"):
    """``_local_scan`` inside shard_map over the ``clients`` mesh axis.

    Each device trains only the clients it owns (round-robin layout from
    ``stacking``; K > n_devices spills extra clients as second/third slots)
    and the phase runs with ZERO cross-device collectives — private data
    never leaves its device, matching the paper's locality claim.

    The round-robin reorder/pad runs EAGERLY, outside the jitted shard_map
    program: an in-jit gather feeding shard_map lets XLA's layout
    assignment propagate non-standard layouts into the per-device body,
    whose convs/GEMMs then round differently from the unsharded engine.
    """
    n_dev = mesh.shape[stacking.CLIENT_AXIS]
    shard = lambda t: stacking.shard_clients(t, n_clients, n_dev)
    run = _sharded_local_program(mesh, n_clients, vn_cfg, sgd_cfg,
                                 conv_impl)
    p, o, losses = run(shard(stacked_params), shard(stacked_opt),
                       shard(images), shard(labels), shard(masks),
                       shard(keys))
    unshard = lambda t: stacking.unshard_clients(t, n_clients, n_dev)
    return unshard(p), unshard(o), unshard(losses)


def _isolated_epoch(epoch):
    """Pin a scan body as its own compilation unit.  XLA inlines
    trip-count-1 loops (mutual_epochs=1 is the default), and an inlined
    epoch fuses with its surroundings — which differ between the sharded
    and unsharded engines — breaking their bitwise parity."""
    def wrapped(carry, xs):
        carry, xs = jax.lax.optimization_barrier((carry, xs))
        return jax.lax.optimization_barrier(epoch(carry, xs))
    return wrapped


def _predict_chunked(stacked_params, images, vn_cfg: VisionNetConfig):
    """Dropout-free stacked forward in canonical client chunks: (K, B)."""
    K = jax.tree.leaves(stacked_params)[0].shape[0]
    fn = lambda a, c: jax.vmap(
        lambda q: visionnet_forward(q, vn_cfg, c[0], train=False))(a[0])
    return stacking.chunked_client_map(fn, (stacked_params,), K,
                                       const_args=(images,))


def _mutual_epoch_step(stacked_params, stacked_opt, keys_e, pm_rows,
                       pair_rows, shared, pub_images, pub_labels,
                       vn_cfg: VisionNetConfig, sgd_cfg: SGDConfig,
                       kl_weight: float, conv_impl: str):
    """One Eq.-1 descent for a stack of clients against FIXED shared
    predictions.

    ``shared`` (K, B) is the fleet's dropout-free public-fold predictions
    in natural client order (already stop-gradient'ed: received predictions
    are data); ``pair_rows`` the matching rows of the Eq.-2 pair mask, and
    ``pm_rows`` the rows' participation bits.  Runs in canonical width-2
    chunks, so the unsharded engine (full K rows) and each device of the
    sharded engine (its K_loc rows) execute bit-identical per-client
    arithmetic.  Returns (params, opt, (bce, kld)).
    """

    def chunk(args, const):
        c_params, c_opt, c_keys, c_pm, c_w = args
        c_shared, c_imgs, c_labs = const

        def total_loss(cp):
            live = jax.vmap(
                lambda q, k: visionnet_forward(q, vn_cfg, c_imgs,
                                               train=True, dropout_key=k,
                                               conv_impl=conv_impl)
            )(cp, c_keys)                                       # (2,B)
            bce = jax.vmap(lambda pr: bce_loss(pr, c_labs))(live)
            kld = jnp.mean(bernoulli_mutual_terms_vs(live, c_shared, c_w),
                           axis=-1)                             # (2,)
            return (jnp.sum(bce * c_pm) + kl_weight * jnp.sum(kld),
                    (bce, kld))

        (_, (bce, kld)), grads = jax.value_and_grad(
            total_loss, has_aux=True)(c_params)
        # per-client update so grad clipping stays per client, exactly as
        # in the per-client loop this replaces
        new_p, new_o, _ = jax.vmap(
            lambda q, g, o: sgd_update(q, g, o, sgd_cfg))(c_params, grads,
                                                          c_opt)
        p = jax.vmap(_masked_lerp)(c_params, new_p, c_pm)
        o = {"vel": jax.vmap(_masked_lerp)(c_opt["vel"], new_o["vel"],
                                           c_pm),
             "step": c_opt["step"] + c_pm.astype(jnp.int32)}
        return p, o, (bce, kld)

    K = jax.tree.leaves(stacked_params)[0].shape[0]
    return stacking.chunked_client_map(
        chunk, (stacked_params, stacked_opt, keys_e, pm_rows, pair_rows), K,
        const_args=(shared, pub_images, pub_labels))


def _robust_epoch_step(stacked_params, stacked_opt, keys_e, pm_rows,
                       target, pub_images, pub_labels,
                       vn_cfg: VisionNetConfig, sgd_cfg: SGDConfig,
                       kl_weight: float, conv_impl: str):
    """One Eq.-1 descent against FIXED per-client consensus targets.

    Same chunked structure as ``_mutual_epoch_step``, but the Eq.-2 mean
    over received predictions is replaced by per-client target rows
    (``target`` (K, B) — already robustly aggregated over the received
    payloads and held fixed); absentees get zero KL weight AND a masked
    update.  Returns (params, opt, (bce, kld)).
    """

    def chunk(args, const):
        c_params, c_opt, c_keys, c_pm, c_tgt = args
        c_imgs, c_labs = const

        def total_loss(cp):
            live = jax.vmap(
                lambda q, k: visionnet_forward(q, vn_cfg, c_imgs,
                                               train=True, dropout_key=k,
                                               conv_impl=conv_impl)
            )(cp, c_keys)                                       # (2,B)
            bce = jax.vmap(lambda pr: bce_loss(pr, c_labs))(live)
            kld = jnp.mean(bernoulli_kl_to_target(live, c_tgt),
                           axis=-1) * c_pm                      # (2,)
            return (jnp.sum(bce * c_pm) + kl_weight * jnp.sum(kld),
                    (bce, kld))

        (_, (bce, kld)), grads = jax.value_and_grad(
            total_loss, has_aux=True)(c_params)
        new_p, new_o, _ = jax.vmap(
            lambda q, g, o: sgd_update(q, g, o, sgd_cfg))(c_params, grads,
                                                          c_opt)
        p = jax.vmap(_masked_lerp)(c_params, new_p, c_pm)
        o = {"vel": jax.vmap(_masked_lerp)(c_opt["vel"], new_o["vel"],
                                           c_pm),
             "step": c_opt["step"] + c_pm.astype(jnp.int32)}
        return p, o, (bce, kld)

    K = jax.tree.leaves(stacked_params)[0].shape[0]
    return stacking.chunked_client_map(
        chunk, (stacked_params, stacked_opt, keys_e, pm_rows, target), K,
        const_args=(pub_images, pub_labels))


@functools.partial(jax.jit, static_argnames=("vn_cfg", "sgd_cfg",
                                             "kl_weight", "conv_impl"))
def _mutual_scan(stacked_params, stacked_opt, pub_images, pub_labels, keys,
                 part_mask, vn_cfg: VisionNetConfig, sgd_cfg: SGDConfig,
                 kl_weight: float, conv_impl: str = "fused"):
    """All mutual epochs for all K clients, fused into one program.

    keys (E, K, 2) · part_mask (K,) 0/1.  Per epoch: every participant
    shares its dropout-free predictions on the public fold (what actually
    goes over the wire), then descends Eq. 1 — BCE + kl_weight · KLD vs the
    received tensor held fixed.  Partial participation masks absentees out
    of the Eq.-2 average AND out of the update (their params/opt ride
    through unchanged).  Returns the final epoch's per-client
    (total loss, bce, kld), each (K,).
    """
    K = jax.tree.leaves(stacked_params)[0].shape[0]
    pair_w = _pair_mask(K, part_mask)

    def epoch(carry, ks):
        params, opt = carry
        shared = jax.lax.stop_gradient(
            _predict_chunked(params, pub_images, vn_cfg))          # (K,B)
        params, opt, (bce, kld) = _mutual_epoch_step(
            params, opt, ks, part_mask, pair_w, shared, pub_images,
            pub_labels, vn_cfg, sgd_cfg, kl_weight, conv_impl)
        return (params, opt), (bce + kl_weight * kld, bce, kld)

    (stacked_params, stacked_opt), (loss, bce, kld) = jax.lax.scan(
        _isolated_epoch(epoch), (stacked_params, stacked_opt), keys)
    return stacked_params, stacked_opt, (loss[-1], bce[-1], kld[-1])


@functools.partial(jax.jit, static_argnames=("vn_cfg", "sgd_cfg",
                                             "kl_weight", "conv_impl",
                                             "robust_mode", "trim"))
def _mutual_scan_ext(stacked_params, stacked_opt, pub_images, pub_labels,
                     keys, part_mask, byz_sign, byz_collude, dp_clip,
                     dp_sigma, noise_keys, vn_cfg: VisionNetConfig,
                     sgd_cfg: SGDConfig, kl_weight: float,
                     conv_impl: str = "fused", robust_mode: str = "mean",
                     trim: int = 0):
    """Extended mutual program: payload poisoning → DP release → combine.

    The PLAIN protocol keeps the untouched ``_mutual_scan`` program (its
    bitwise parity with the legacy trainers is load-bearing); every
    privacy/robustness feature routes through this program instead.

    keys (E, K, 2) dropout keys · noise_keys (E, 2) one DP key per epoch ·
    byz_sign / byz_collude (K,) 0/1 Byzantine masks · dp_clip / dp_sigma
    scalars (sigma = 0 makes the DP stage an exact bitwise no-op).  Per
    epoch: participants predict on the public fold; Byzantine senders
    replace their payload on the wire (sign-flip: p → 1−p; collude:
    confident mass on the wrong label) — their own training still sees
    honest receipts, the attack is on what they SEND; the stacked payload
    is then clipped + Gaussian-noised (``privacy.dp``) and combined either
    by the Eq.-2 mean (robust_mode='mean') or by a trimmed/median
    consensus target.  Besides the usual (params, opt, losses) it returns
    the per-epoch ON-WIRE payloads (E, K, B) — exactly what an
    eavesdropping adversary observes — for the attack probes.
    """
    K = jax.tree.leaves(stacked_params)[0].shape[0]
    pair_w = _pair_mask(K, part_mask)
    wrong = jnp.clip(1.0 - pub_labels.astype(jnp.float32),
                     0.02, 0.98)[None, :]                        # (1,B)
    sf = byz_sign[:, None]
    cl = byz_collude[:, None]

    def epoch(carry, xs):
        ks, nk = xs
        params, opt = carry
        shared = jax.lax.stop_gradient(
            _predict_chunked(params, pub_images, vn_cfg))        # (K,B)
        shared = (1.0 - sf - cl) * shared + sf * (1.0 - shared) + cl * wrong
        shared = jax.lax.stop_gradient(
            dp_probs_payload(shared, dp_clip, dp_sigma, nk))
        if robust_mode == "mean":
            params, opt, (bce, kld) = _mutual_epoch_step(
                params, opt, ks, part_mask, pair_w, shared, pub_images,
                pub_labels, vn_cfg, sgd_cfg, kl_weight, conv_impl)
        else:
            target = robust_bernoulli_target(shared, part_mask,
                                             robust_mode, trim)
            params, opt, (bce, kld) = _robust_epoch_step(
                params, opt, ks, part_mask, target, pub_images,
                pub_labels, vn_cfg, sgd_cfg, kl_weight, conv_impl)
        return (params, opt), (bce + kl_weight * kld, bce, kld, shared)

    (stacked_params, stacked_opt), (loss, bce, kld, pay) = jax.lax.scan(
        _isolated_epoch(epoch), (stacked_params, stacked_opt),
        (keys, noise_keys))
    return (stacked_params, stacked_opt, (loss[-1], bce[-1], kld[-1]),
            pay)


@functools.lru_cache(maxsize=None)
def _sharded_mutual_program(mesh, n_clients: int, vn_cfg: VisionNetConfig,
                            sgd_cfg: SGDConfig, kl_weight: float,
                            conv_impl: str):
    n_dev = mesh.shape[stacking.CLIENT_AXIS]

    def body(params, opt, pub_imgs, pub_labs, ks, pm_full):
        gids = stacking.local_client_ids(n_clients, n_dev)
        safe = jnp.minimum(gids, n_clients - 1)
        real = (gids < n_clients).astype(jnp.float32)    # 0 on dummy slots
        pm_loc = jnp.take(pm_full, safe) * real
        pair_rows = jnp.take(_pair_mask(n_clients, pm_full), safe,
                             axis=0) * real[:, None]

        def epoch(carry, kk):
            params, opt = carry
            shared_loc = _predict_chunked(params, pub_imgs,
                                          vn_cfg)        # (K_loc, B)
            shared = jax.lax.stop_gradient(stacking.gather_clients(
                shared_loc, n_clients, n_dev)[:n_clients])  # (K, B) natural
            params, opt, (bce, kld) = _mutual_epoch_step(
                params, opt, kk, pm_loc, pair_rows, shared, pub_imgs,
                pub_labs, vn_cfg, sgd_cfg, kl_weight, conv_impl)
            return (params, opt), (bce + kl_weight * kld, bce, kld)

        (params, opt), (loss, bce, kld) = jax.lax.scan(
            _isolated_epoch(epoch), (params, opt), ks)
        return params, opt, (loss[-1], bce[-1], kld[-1])

    spec = stacking.client_spec()
    return jax.jit(sharding.shard_map(
        body, mesh,
        in_specs=(spec, spec, P(), P(), P(None, stacking.CLIENT_AXIS), P()),
        out_specs=(spec, spec, (spec, spec, spec))))


def _sharded_mutual_scan(stacked_params, stacked_opt, pub_images, pub_labels,
                         keys, part_mask, mesh, n_clients: int,
                         vn_cfg: VisionNetConfig, sgd_cfg: SGDConfig,
                         kl_weight: float, conv_impl: str = "fused"):
    """``_mutual_scan`` inside shard_map over the ``clients`` mesh axis.

    Per mutual epoch each device forwards its own clients on the public
    fold and the (K_loc, B_pub) predictions are all-gathered — the ONLY
    cross-device collective of the whole round, and precisely the tensor
    Algorithm 1 says crosses client boundaries.  The gathered fleet is
    restored to natural client order (``stacking.gather_clients``) before
    the Eq.-2 sum so reduction order — and hence every float — matches the
    unsharded engine bitwise.  Each device then descends Eq. 1 for its own
    clients only (rows of the pair-mask select them); dummies from the
    round-robin padding are masked out of both the average and the update.
    The reorder/pad runs eagerly outside the jitted program (see
    ``_sharded_local_scan`` — in-jit gathers perturb body layouts).
    """
    n_dev = mesh.shape[stacking.CLIENT_AXIS]
    run = _sharded_mutual_program(mesh, n_clients, vn_cfg, sgd_cfg,
                                  kl_weight, conv_impl)
    p, o, (loss, bce, kld) = run(
        stacking.shard_clients(stacked_params, n_clients, n_dev),
        stacking.shard_clients(stacked_opt, n_clients, n_dev),
        pub_images, pub_labels,
        stacking.shard_clients(keys, n_clients, n_dev, axis=1),
        jnp.asarray(part_mask, jnp.float32))
    unshard = lambda t: stacking.unshard_clients(t, n_clients, n_dev)
    return unshard(p), unshard(o), (unshard(loss), unshard(bce),
                                    unshard(kld))


@functools.partial(jax.jit, static_argnames=("vn_cfg",))
def _predict_stacked(stacked_params, images, vn_cfg: VisionNetConfig):
    """Vmapped inference on a SHARED batch: (K-stacked params, (B,...)) ->
    (K, B) probabilities.  The sharing / eval / accuracy path."""
    return jax.vmap(lambda p: visionnet_forward(p, vn_cfg, images,
                                                train=False))(stacked_params)


@functools.partial(jax.jit, static_argnames=("vn_cfg",))
def _accuracy_scan(stacked_params, images, labels, masks,
                   vn_cfg: VisionNetConfig):
    """Per-client accuracy on per-client (padded) data:
    images (K,N,H,W,C) · labels (K,N) · masks (K,N) -> (K,)."""
    probs = jax.vmap(
        lambda p, im: visionnet_forward(p, vn_cfg, im, train=False)
    )(stacked_params, images)
    hit = ((probs > 0.5) == (labels > 0.5)).astype(jnp.float32)
    return jnp.sum(hit * masks, axis=1) / jnp.maximum(
        jnp.sum(masks, axis=1), 1.0)


# ---------------------------------------------------------------------------
# the population


class VisionClients(Population):
    """K stacked VisionNet clients on a (train_images, train_labels) pool.

    ``mesh``: optional jax Mesh with a ``clients`` axis — the round's two
    training programs then run device-sharded over the client axis
    (bitwise-identical results; see the sharded program docstrings).

    ``byzantine``: ``{client_index: mode}`` marks adversarial clients —
    ``"label-flip"`` poisons their LOCAL training labels, ``"sign-flip"``
    inverts the predictions they share (p → 1−p), ``"collude"`` makes
    them share confident mass on the wrong public label.  ``record_payloads``
    keeps every round's on-wire prediction payloads in ``payload_log``
    (the attack probes' observation tap).  Either feature routes the
    mutual phase through the extended program, which is unsharded-only.
    """

    engine_name = "federated"
    supported = frozenset({"dml", "fedavg", "async",
                           "dp-dml", "trimmed-dml", "median-dml"})
    _BYZ_MODES = ("label-flip", "sign-flip", "collude")

    def __init__(self, vn_cfg: VisionNetConfig, train_images: np.ndarray,
                 train_labels: np.ndarray, n_clients: int = 5,
                 rounds: int = 12, local_epochs: int = 2,
                 batch_size: int = 32, lr: float = 0.05,
                 momentum: float = 0.9, clip_norm: float = 1.0,
                 non_iid_alpha: float = 0.0, seed: int = 0,
                 eval_batch: int = 256, byzantine=None,
                 record_payloads: bool = False, mesh=None):
        if mesh is not None and stacking.CLIENT_AXIS not in mesh.axis_names:
            raise ValueError(
                f"mesh needs a '{stacking.CLIENT_AXIS}' axis, got "
                f"{mesh.axis_names}")
        self.mesh = mesh
        self.byzantine = {int(c): m for c, m in (byzantine or {}).items()}
        for c, mode in self.byzantine.items():
            if not 0 <= c < n_clients:
                raise ValueError(
                    f"byzantine client {c} out of range (K={n_clients})")
            if mode not in self._BYZ_MODES:
                raise ValueError(
                    f"unknown byzantine mode {mode!r} for client {c}; "
                    f"VisionClients supports {self._BYZ_MODES}")
        self._flip_rows = sorted(c for c, m in self.byzantine.items()
                                 if m == "label-flip")
        self.record_payloads = bool(record_payloads)
        self.payload_log: List[dict] = []
        self.fold_log: List[list] = []
        self.vn_cfg = vn_cfg
        self.images = train_images
        self.labels = train_labels
        self.n_clients = n_clients
        self.rounds = rounds
        self.local_epochs = local_epochs
        self.batch_size = batch_size
        self.eval_batch = eval_batch
        self.seed = seed
        self.sgd_cfg = SGDConfig(lr=lr, momentum=momentum,
                                 clip_norm=clip_norm)
        self.key = jax.random.PRNGKey(seed)
        self._plan_seed = seed * 100_003 + 17
        # (round, program) pairs — one entry per jitted dispatch, so tests
        # can assert the engine really is a handful of programs per round
        self.dispatch_log: List[Tuple[int, str]] = []
        self._round_idx = -1                      # -1 = init phase
        # Algorithm 1 line 1: Fold <- (1+Clients) x Rounds + 1
        if non_iid_alpha > 0:
            self.folds = NonIIDScheduler(train_labels, n_clients, rounds,
                                         alpha=non_iid_alpha, seed=seed)
        else:
            self.folds = FoldScheduler(train_labels, n_clients, rounds,
                                       seed=seed)
        # line 3/6: global model trained on public fold
        self.key, kg = jax.random.split(self.key)
        self.global_params = init_visionnet(kg, vn_cfg)
        self.global_opt = sgd_init(self.global_params)
        self._train_single(self.folds.pop())
        # lines 7-8: clients start from G
        self.client_params = stacking.broadcast_stack(self.global_params,
                                                      n_clients)
        self.client_opts = stacking.stacked_sgd_init(self.client_params)
        self.n_params = sum(p.size
                            for p in jax.tree.leaves(self.global_params))
        self.shallow_mask = shallow_deep_split(self.global_params)
        self._last_folds: Optional[list] = None

    def validate_strategy(self, strategy) -> None:
        if strategy.name == "sparse-dml":
            raise ValueError(
                "sparse-dml needs a categorical prediction space to take a "
                "top-k of; the stacked VisionNet population shares Bernoulli "
                "probabilities (one float per example).  Use DML here, or "
                "SparseDML with the hetero / LM populations.")
        super().validate_strategy(strategy)

    # -- helpers ----------------------------------------------------------
    def begin_round(self, r: int) -> None:
        self._round_idx = r

    def _next_plan_seed(self) -> int:
        self._plan_seed += 1
        return self._plan_seed

    def _split_keys(self, *shape) -> jax.Array:
        """Dropout keys for a whole program at once: (*shape, 2) uint32."""
        self.key, sub = jax.random.split(self.key)
        n = int(np.prod(shape))
        return jax.random.split(sub, n).reshape(*shape, 2)

    def _gather(self, idx: np.ndarray):
        return jnp.asarray(self.images[idx]), jnp.asarray(self.labels[idx])

    def _train_single(self, fold: np.ndarray) -> float:
        """Global-model training = the SAME scan program with K=1."""
        idx, mask = round_batch_indices([fold], self.local_epochs,
                                        self.batch_size,
                                        seed=self._next_plan_seed())
        if idx.shape[1] == 0:
            return 0.0
        imgs, labs = self._gather(idx)
        keys = self._split_keys(1, idx.shape[1])
        gp = stacking.expand_stack(self.global_params)
        go = stacking.expand_stack(self.global_opt)
        gp, go, losses = _local_scan(gp, go, imgs, labs, jnp.asarray(mask),
                                     keys, self.vn_cfg, self.sgd_cfg,
                                     conv_impl="native")
        self.dispatch_log.append((self._round_idx, "local_scan"))
        self.global_params = stacking.client_slice(gp, 0)
        self.global_opt = stacking.client_slice(go, 0)
        return float(losses[0])

    def _local_round(self, part_mask: Optional[np.ndarray] = None):
        """Pop K client folds and run every client's local epochs in ONE
        vmapped scan dispatch.  Returns (folds, per-client mean loss).

        ``part_mask`` (K,) 0/1 zeroes the whole batch plan of absent
        clients — their params/opt ride through the scan untouched (the
        masked-lerp padding path), exactly as if they never trained.
        """
        K = self.n_clients
        folds, idx, mask = self.folds.pop_round(
            K, self.local_epochs, self.batch_size,
            seed=self._next_plan_seed())
        if idx.shape[1] == 0:
            return folds, [0.0] * K
        if part_mask is not None:
            mask = mask * part_mask[:, None]
        imgs, labs = self._gather(idx)
        if self._flip_rows:
            rows = jnp.asarray(self._flip_rows)
            labs = labs.at[rows].set(1 - labs[rows])
        keys = self._split_keys(K, idx.shape[1])
        if self.mesh is not None and K > 1:
            self._to_mesh()
            self.client_params, self.client_opts, losses = \
                _sharded_local_scan(self.client_params, self.client_opts,
                                    imgs, labs, jnp.asarray(mask), keys,
                                    self.mesh, K, self.vn_cfg, self.sgd_cfg,
                                    conv_impl="fused")
        else:
            self.client_params, self.client_opts, losses = _local_scan(
                self.client_params, self.client_opts, imgs, labs,
                jnp.asarray(mask), keys, self.vn_cfg, self.sgd_cfg,
                conv_impl="fused" if K > 1 else "native")
        self.dispatch_log.append((self._round_idx, "local_scan"))
        return folds, [float(x) for x in np.asarray(losses)]

    def _gather_clients_host(self):
        """Commit the (possibly client-sharded) client state to one device.
        The weight-sharing baselines gather every client's weights by
        definition; doing it explicitly keeps their sync math — reduction
        order included — bitwise-identical to the unsharded engine."""
        if self.mesh is None:
            return
        dev = jax.devices()[0]
        self.client_params = jax.device_put(self.client_params, dev)
        self.client_opts = jax.device_put(self.client_opts, dev)

    def _to_mesh(self):
        """Re-place single-device-committed client state onto the mesh
        (after a weight-sharing sync gathered it) so the sharded programs
        see consistent devices; DML chains keep their sharded placement."""
        leaf = jax.tree.leaves(self.client_params)[0]
        if not isinstance(getattr(leaf, "sharding", None),
                          jax.sharding.SingleDeviceSharding):
            return
        sh = jax.sharding.NamedSharding(self.mesh, P())
        self.client_params = jax.device_put(self.client_params, sh)
        self.client_opts = jax.device_put(self.client_opts, sh)

    def _fold_accuracies(self, folds) -> List[float]:
        """Each client scored on its OWN fold — one vmapped dispatch over a
        padded (K, N) stack (the async baseline's weighting metric)."""
        n = max(max((len(f) for f in folds), default=0), 1)
        K = len(folds)
        idx = np.zeros((K, n), np.int64)
        mask = np.zeros((K, n), np.float32)
        for c, f in enumerate(folds):
            idx[c, :len(f)] = f
            mask[c, :len(f)] = 1.0
        imgs, labs = self._gather(idx)
        acc = _accuracy_scan(self.client_params, imgs, labs,
                             jnp.asarray(mask), self.vn_cfg)
        self.dispatch_log.append((self._round_idx, "accuracy_scan"))
        return [float(a) for a in np.asarray(acc)]

    def _accuracy_chunked(self, stacked_params, images, labels) -> np.ndarray:
        """All clients' accuracy on a SHARED dataset via the vmapped
        predict, eval_batch examples at a time.  Returns (K,)."""
        K = jax.tree.leaves(stacked_params)[0].shape[0]
        correct = np.zeros((K,), np.int64)
        for i in range(0, len(images), self.eval_batch):
            probs = _predict_stacked(stacked_params,
                                     jnp.asarray(images[i:i + self.eval_batch]),
                                     self.vn_cfg)
            self.dispatch_log.append((self._round_idx, "predict"))
            correct += np.sum((np.asarray(probs) > 0.5) ==
                              labels[None, i:i + self.eval_batch], axis=1)
        return correct / len(images)

    # -- strategy capabilities --------------------------------------------
    def local_phase(self, r: int, part: List[int], pm) -> List[float]:
        K = self.n_clients
        folds, losses = self._local_round(pm if len(part) < K else None)
        self._last_folds = folds
        if self.record_payloads:
            # per-client private-fold indices — the attack probes' member
            # ground truth (indices only; the pool itself is not copied)
            self.fold_log.append([np.asarray(f) for f in folds])
        return losses

    def public_payload(self, r: int):
        # public fold: rotating common test set from the server
        return self.folds.pop()

    def weights_payload(self, r: int):
        return self.folds.pop()

    def _byz_payload_masks(self):
        sf = np.zeros((self.n_clients,), np.float32)
        cl = np.zeros((self.n_clients,), np.float32)
        for c, mode in self.byzantine.items():
            if mode == "sign-flip":
                sf[c] = 1.0
            elif mode == "collude":
                cl[c] = 1.0
        return sf, cl

    def mutual_phase(self, r, part, pm, payload, kl_weight, mutual_epochs,
                     sparse_k: int = 0, dp=None, robust=None) -> dict:
        K = self.n_clients
        pub = payload.data
        out = {"ran": False, "positions": len(pub)}
        sf, cl = self._byz_payload_masks()
        # any privacy/robustness feature — including the payload tap —
        # diverts to the extended program so the plain program (whose
        # bitwise parity with the legacy trainers is pinned by tests)
        # never changes
        ext = (dp is not None or robust is not None or sf.any() or cl.any()
               or self.record_payloads)
        if ext and self.mesh is not None:
            raise NotImplementedError(
                "DP / Byzantine / robust-combine / payload recording run "
                "on the unsharded engine only; drop mesh= or the feature")
        if mutual_epochs > 0 and len(part) >= 2:
            pub_imgs = jnp.asarray(self.images[pub])
            pub_labs = jnp.asarray(self.labels[pub])
            keys = self._split_keys(mutual_epochs, K)
            if self.mesh is not None and K > 1:
                self.client_params, self.client_opts, (loss, _, kld) = \
                    _sharded_mutual_scan(self.client_params,
                                         self.client_opts, pub_imgs,
                                         pub_labs, keys, jnp.asarray(pm),
                                         self.mesh, K, self.vn_cfg,
                                         self.sgd_cfg, kl_weight,
                                         conv_impl="fused")
            elif not ext:
                self.client_params, self.client_opts, (loss, _, kld) = \
                    _mutual_scan(self.client_params, self.client_opts,
                                 pub_imgs, pub_labs, keys, jnp.asarray(pm),
                                 self.vn_cfg, self.sgd_cfg, kl_weight,
                                 conv_impl="fused" if K > 1 else "native")
            else:
                mode, trim = ("mean", 0) if robust is None else robust
                if dp is not None:
                    dp_clip, dp_sigma = dp.clip, dp.noise_multiplier
                    nkeys = dp.keys
                else:
                    dp_clip, dp_sigma = 1.0, 0.0     # exact no-op gate
                    nkeys = jax.random.split(jax.random.PRNGKey(0),
                                             mutual_epochs)
                (self.client_params, self.client_opts, (loss, _, kld),
                 pay) = _mutual_scan_ext(
                    self.client_params, self.client_opts, pub_imgs,
                    pub_labs, keys, jnp.asarray(pm), jnp.asarray(sf),
                    jnp.asarray(cl), float(dp_clip), float(dp_sigma),
                    nkeys, self.vn_cfg, self.sgd_cfg, kl_weight,
                    conv_impl="fused" if K > 1 else "native",
                    robust_mode=mode, trim=int(trim))
                if self.record_payloads:
                    self.payload_log.append(
                        {"round": r, "public": np.asarray(pub),
                         "payloads": np.asarray(pay)})
            self.dispatch_log.append((r, "mutual_scan"))
            out = {"ran": True, "positions": len(pub),
                   "client_loss": [float(x) * m for x, m in
                                   zip(np.asarray(loss), pm)],
                   "kl_loss": [float(x) for x in np.asarray(kld)]}
        return out

    def fedavg_combine(self, part: List[int], pm) -> None:
        K = self.n_clients
        self._gather_clients_host()
        if len(part) == K:
            self.client_params = fedavg.average_weights(self.client_params)
            avg = self.client_params
        else:
            # server averages the M participants; only they receive the
            # broadcast back (absentees are offline this round)
            avg = fedavg.weighted_average_weights(self.client_params,
                                                  jnp.asarray(pm))
            self.client_params = stacking.client_lerp(self.client_params,
                                                      avg, pm)
        self.global_params = stacking.client_slice(avg, 0)

    def async_combine(self, r, part, pm, delta, min_round, pub) -> str:
        K = self.n_clients
        self._gather_clients_host()
        scores = self._fold_accuracies(self._last_folds)
        # absentees contribute no weight to the aggregate and receive none
        # of it back (scores masked -> their average weight is 0)
        masked_scores = jnp.asarray(np.asarray(scores) * pm)
        synced, layer = async_fl.async_round_update(
            self.client_params, masked_scores, self.shallow_mask, r,
            delta, min_round)
        # Algorithm 1 lines 17-18: G takes the aggregate then trains on a
        # fold — sliced from the SYNCED tree (where every client received
        # the round's average), not from the lerped one below where an
        # absent client 0 would hand G its stale params
        self.global_params = stacking.client_slice(synced, 0)
        if len(part) < K:
            synced = stacking.client_lerp(self.client_params, synced, pm)
        self.client_params = synced
        self._train_single(pub)
        return layer

    def async_param_counts(self):
        return async_fl.count_params_by_mask(self.global_params,
                                             self.shallow_mask)

    @property
    def params_per_client(self) -> int:
        return self.n_params

    # -- final eval (paper Table II / Fig. 3) ------------------------------
    def evaluate(self, history, split=None):
        if split is None:
            raise ValueError(
                "the stacked VisionNet population scores clients on a "
                "held-out dataset: evaluate(split=(test_images, "
                "test_labels))")
        test_images, test_labels = split
        self._round_idx = self.rounds                  # eval phase
        self._gather_clients_host()
        history.client_test_acc = [
            float(a) for a in self._accuracy_chunked(
                self.client_params, test_images, test_labels)]
        gp = stacking.expand_stack(self.global_params)
        history.global_test_acc = float(self._accuracy_chunked(
            gp, test_images, test_labels)[0])
        return history

    # -- checkpoint/resume -------------------------------------------------
    def state_dict(self) -> dict:
        return {
            "client_params": self.client_params,
            "client_opts": self.client_opts,
            "global_params": self.global_params,
            "global_opt": self.global_opt,
            "key": jax.random.key_data(self.key)
            if jnp.issubdtype(self.key.dtype, jax.dtypes.prng_key)
            else self.key,
        }

    def meta_dict(self) -> dict:
        return {
            "engine": self.engine_name,
            "n_clients": self.n_clients,
            "n_rounds": self.rounds,
            "pool_n": len(self.labels),
            "plan_seed": self._plan_seed,
            "scheduler": self.folds.state(),
        }

    def check_meta(self, meta: dict) -> None:
        if meta.get("n_clients") != self.n_clients:
            raise ValueError(
                f"checkpoint K={meta.get('n_clients')} != config "
                f"K={self.n_clients}")
        # fold partition is deterministic in (labels, K, rounds, seed); a
        # different schedule/pool would silently resume on the wrong folds
        if meta.get("n_rounds", self.rounds) != self.rounds or \
                meta.get("pool_n", len(self.labels)) != len(self.labels):
            raise ValueError(
                f"checkpoint schedule (rounds={meta.get('n_rounds')}, "
                f"pool={meta.get('pool_n')}) != config "
                f"(rounds={self.rounds}, pool={len(self.labels)}); "
                "resume needs the same fold partition — save with the full "
                "round budget and stop early via run(until=...)")

    def load_state_dict(self, state: dict, meta: dict) -> None:
        self.client_params = state["client_params"]
        self.client_opts = state["client_opts"]
        self.global_params = state["global_params"]
        self.global_opt = state["global_opt"]
        self.key = jnp.asarray(state["key"])
        self._plan_seed = int(meta["plan_seed"])
        self.folds.load_state(meta["scheduler"])
