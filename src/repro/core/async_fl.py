"""Asynchronous weight-updating FL (the paper's baseline #2, after [4]).

Algorithm 1's schedule: shallow layers are aggregated every round; deep
layers only when ``(round+1) % delta == 0 and round >= min_round``.
Aggregation is the metric-weighted average (``preprocessWeights`` +
``averageWeights``), and ``updateWeights`` overwrites only the scheduled
param group.  A server-side global model G is trained on a held-out global
split each round (Algorithm 1 lines 6, 17-18).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.fedavg import weighted_average_weights

Mask = Any  # pytree of bools parallel to params


def layer_schedule(round_idx: int, delta: int = 3, min_round: int = 5) -> str:
    """Algorithm 1 lines 12-14: 'shallow' or 'deep' for this round."""
    if (round_idx + 1) % delta == 0 and round_idx >= min_round:
        return "deep"
    return "shallow"


def update_weights(stacked_params, avg_params, shallow_mask: Mask,
                   layer: str):
    """Overwrite the scheduled group with the aggregate.

    layer='shallow': shallow-mask leaves take the average (every round).
    layer='deep':    deep (non-shallow) leaves take the average (every
                     delta-th round).  Clients never fully sync — matching
                     paper Table II, where async clients end with distinct
                     accuracies, and Fig. 4's light/dark sharing shades.
    """
    want_shallow = layer == "shallow"
    return jax.tree.map(
        lambda sh, p, a: a if sh == want_shallow else p, shallow_mask,
        stacked_params, avg_params)


def async_round_update(stacked_params, scores, shallow_mask: Mask,
                       round_idx: int, delta: int = 3, min_round: int = 5):
    """One aggregation of the async baseline on client-stacked params."""
    layer = layer_schedule(round_idx, delta, min_round)
    avg = weighted_average_weights(stacked_params, scores)
    return update_weights(stacked_params, avg, shallow_mask, layer), layer


def comm_bytes_per_round(n_shallow: int, n_deep: int, n_clients: int,
                         layer: str, bytes_per_param: int = 4) -> int:
    n = n_deep if layer == "deep" else n_shallow
    return 2 * n_clients * n * bytes_per_param


def count_params_by_mask(params, shallow_mask: Mask):
    flat_p = jax.tree.leaves(params)
    flat_m = jax.tree.leaves(shallow_mask)
    n_shallow = sum(p.size for p, m in zip(flat_p, flat_m) if m)
    n_deep = sum(p.size for p, m in zip(flat_p, flat_m) if not m)
    return n_shallow, n_deep
