"""Back-compat shim: the heterogeneous-client DML trainer as a thin
wrapper over the unified session API.

The engine now lives in ``core.populations.hetero.HeteroClients`` (the
per-client model registry, per-arch jitted programs, fold discipline)
composed with a ``core.strategies`` sharing strategy by
``core.api.Federation``.  ``HeteroTrainer`` keeps the original
constructor/`run`/`evaluate()`/checkpoint surface and reproduces the
pre-API engine bitwise; its ``save_state`` files restore into a
``Federation`` unchanged.  ``make_lm_pool`` and ``comm_bytes_per_round``
re-export from the population module.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

from repro.core.api import Federation, History, RoundLog
from repro.core.populations.hetero import (HeteroClients,
                                           comm_bytes_per_round,
                                           make_lm_pool)  # noqa: F401
from repro.core.strategies import DML, SparseDML

# legacy names (the hetero engine predates the unified History)
HeteroHistory = History
HeteroRoundLog = RoundLog


@dataclass
class HeteroConfig:
    archs: Tuple[str, ...] = ("qwen3-4b", "mamba2-780m", "dbrx-132b")
    rounds: int = 4
    local_epochs: int = 1
    batch_size: int = 4
    public_batch: int = 4         # examples of the public fold actually used
    lr: float = 3e-3
    kl_weight: float = 1.0
    mutual_epochs: int = 1
    participation: int = 0        # M <= K clients sampled per round; 0 -> K
    sparse_k: int = 0             # > 0: share top-k predictions (SparseDML)
    seed: int = 0

    @property
    def n_clients(self) -> int:
        return len(self.archs)

    def strategy(self):
        if self.sparse_k:
            return SparseDML(k=self.sparse_k, kl_weight=self.kl_weight,
                             mutual_epochs=self.mutual_epochs)
        return DML(kl_weight=self.kl_weight,
                   mutual_epochs=self.mutual_epochs)


class HeteroTrainer:
    """Legacy facade: ``Federation(HeteroClients(...), cfg.strategy())``."""

    def __init__(self, cfg: HeteroConfig, data: np.ndarray,
                 labels: np.ndarray, reduced: bool = True):
        self.cfg = cfg
        population = HeteroClients(
            cfg.archs, data, labels, rounds=cfg.rounds,
            local_epochs=cfg.local_epochs, batch_size=cfg.batch_size,
            public_batch=cfg.public_batch, lr=cfg.lr, seed=cfg.seed,
            mutual_updates_per_round=cfg.mutual_epochs, reduced=reduced)
        self.session = Federation(population, cfg.strategy(),
                                  participation=cfg.participation)

    # -- state views --------------------------------------------------------
    @property
    def _pop(self) -> HeteroClients:
        return self.session.population

    @property
    def history(self) -> History:
        return self.session.history

    @property
    def client_params(self):
        return self._pop.client_params

    @client_params.setter
    def client_params(self, value):
        self._pop.client_params = value

    @property
    def client_opts(self):
        return self._pop.client_opts

    @property
    def n_params(self) -> List[int]:
        return self._pop.n_params

    @property
    def n_classes(self) -> int:
        return self._pop.n_classes

    @property
    def folds(self):
        return self._pop.folds

    @property
    def eval_fold(self):
        return self._pop.eval_fold

    @property
    def _models(self):
        return self._pop._models

    @property
    def _round(self) -> int:
        return self.session.round

    def participants(self, r: int) -> List[int]:
        return self.session.participants(r)

    # -- the session API ----------------------------------------------------
    def run(self, until: int = 0) -> History:
        return self.session.run(until=until)

    def evaluate(self) -> History:
        return self.session.evaluate(split=None)

    def save_state(self, path: str) -> None:
        self.session.save_state(path)

    def restore_state(self, path: str) -> None:
        self.session.restore_state(path)
