"""Heterogeneous-client federated mutual learning — the paper's §I
motivation ("different IoT devices ... might use different architectures")
as a first-class engine.

Each client declares its own model family through the per-client registry
(``models.get_client_model``): dense transformer, attention-free SSM,
fine-grained MoE, or the paper's VisionNet.  Weight averaging is undefined
across these clients — the pytrees do not even match — but prediction
sharing does not care: the ONLY tensor that ever crosses a client boundary
is the (K, N_pub, V) stack of public-set logits, so the engine works for
any mix of families that agree on the prediction space V.

Round shape mirrors ``core.federated`` (Algorithm 1):

  1. pop K client folds from the rotating fold schedule (``data.federated``)
     and run each participant's local epochs (per-client jitted ``lax.scan``
     over its fixed-shape (T, B) batch plan — clients cannot be vmapped
     together, but each client is still ONE program per round);
  2. pop the public fold; every mutual epoch each participant publishes its
     eval-mode logits and descends Eq. 1 = CE(public) + kl_weight * Eq. 2
     against the received logits held fixed (``mutual.kl_to_received``);
  3. account communication: logits up + broadcast down, scaling with the
     number of PARTICIPANTS (partial participation: M <= K per round).

Scenario knobs shared with the homogeneous engines:
  - partial participation (``participation``: sample M <= K per round;
    non-participants train nothing, share nothing, receive nothing);
  - checkpoint/resume of the full federated state (per-client params +
    opt + round counter) through ``repro.checkpoint``.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.core.mutual import kl_to_received
from repro.data.federated import (FoldScheduler, round_batch_indices,
                                  sample_participants)
from repro.data.synthetic import make_token_stream
from repro.models import ClientModel, get_client_model
from repro.optim import AdamWConfig, adamw_init, adamw_update


@dataclass
class HeteroConfig:
    archs: Tuple[str, ...] = ("qwen3-4b", "mamba2-780m", "dbrx-132b")
    rounds: int = 4
    local_epochs: int = 1
    batch_size: int = 4
    public_batch: int = 4         # examples of the public fold actually used
    lr: float = 3e-3
    kl_weight: float = 1.0
    mutual_epochs: int = 1
    participation: int = 0        # M <= K clients sampled per round; 0 -> K
    seed: int = 0

    @property
    def n_clients(self) -> int:
        return len(self.archs)


@dataclass
class HeteroRoundLog:
    round: int
    participants: List[int]
    client_loss: List[float]      # local-phase mean loss (0 for absentees)
    public_ce: List[float]        # Eq.-1 model loss on the public fold
    kl_loss: List[float]          # Eq.-2 term (0 for absentees)
    comm_bytes: int


@dataclass
class HeteroHistory:
    rounds: List[HeteroRoundLog] = field(default_factory=list)
    client_eval_loss: List[float] = field(default_factory=list)
    total_comm_bytes: int = 0


def comm_bytes_per_round(n_participants: int, n_pub: int, n_classes: int,
                         mutual_epochs: int,
                         bytes_per_el: int = 4) -> Dict[str, int]:
    """Cost-accounting dict for one heterogeneous DML round.

    Every mutual epoch each of the M participants ships its (N_pub, V)
    logits up and receives the (M, N_pub, V) broadcast down — the same
    up+down convention as the homogeneous engine, with bytes independent
    of any model's parameter count (the paper's bandwidth claim; weight
    averaging is not even defined here).
    """
    per_epoch = n_participants * n_pub * n_classes * bytes_per_el
    return {"per_epoch_up": per_epoch, "per_epoch_down": per_epoch,
            "round": mutual_epochs * 2 * per_epoch}


def make_lm_pool(n_seqs: int, seq_len: int, vocab: int, seed: int = 0,
                 n_domains: int = 4) -> Tuple[np.ndarray, np.ndarray]:
    """Token pool + domain labels for the fold schedule.

    Rows come from ``n_domains`` bigram rules; the domain id doubles as the
    stratification label so every fold mixes all domains (the IID setting).
    """
    per = -(-n_seqs // n_domains)
    parts = [make_token_stream(per, seq_len, vocab, seed=seed + d, domain=d)
             for d in range(n_domains)]
    data = np.concatenate(parts)[:n_seqs]
    labels = np.repeat(np.arange(n_domains), per)[:n_seqs]
    return data, labels.astype(np.int64)


class HeteroTrainer:
    """Runs the Algorithm-1 round loop over architecture-heterogeneous
    clients on a (data, labels) pool.

    ``data``: (N, ...) examples — token streams (N, S) for 'lm' clients,
    images (N, H, W, C) for 'vision' clients.  ``labels``: (N,) ints used
    for stratified folds (and as targets for 'vision' clients).
    """

    def __init__(self, cfg: HeteroConfig, data: np.ndarray,
                 labels: np.ndarray, reduced: bool = True):
        self.cfg = cfg
        self.data = data
        self.labels = labels
        # one ClientModel per unique arch so duplicate-arch clients share
        # jit caches; one params/opt pytree per client
        self._models: Dict[str, ClientModel] = {
            a: get_client_model(a, reduced=reduced) for a in set(cfg.archs)}
        kinds = {m.kind for m in self._models.values()}
        if len(kinds) != 1:
            raise ValueError(f"clients mix modalities {sorted(kinds)}; a "
                             "federation needs one public-set modality")
        spaces = {m.n_classes for m in self._models.values()}
        if len(spaces) != 1:
            raise ValueError(f"clients disagree on the prediction space V "
                             f"({sorted(spaces)}); shared vocab required")
        self.n_classes = spaces.pop()
        self.opt_cfg = AdamWConfig(
            lr=cfg.lr, warmup=2,
            total_steps=max(cfg.rounds * (cfg.local_epochs + cfg.mutual_epochs),
                            1))
        self.base_key = jax.random.PRNGKey(cfg.seed)
        keys = jax.random.split(jax.random.fold_in(self.base_key, 0xC11E47),
                                cfg.n_clients)
        self.client_params = [self._models[a].init(k)
                              for a, k in zip(cfg.archs, keys)]
        self.client_opts = [adamw_init(p) for p in self.client_params]
        self.n_params = [sum(np.size(x) for x in jax.tree.leaves(p))
                         for p in self.client_params]
        # Algorithm-1 fold discipline; the init fold (the homogeneous
        # engine's global-model fold — there is no global model here)
        # becomes a common held-out eval fold
        self.folds = FoldScheduler(labels, cfg.n_clients, cfg.rounds,
                                   seed=cfg.seed)
        min_fold = len(labels) // self.folds.n_folds
        self._pub_n = max(1, min(cfg.public_batch, min_fold))
        self._local_T = cfg.local_epochs * max(1, min_fold // cfg.batch_size)
        self.eval_fold = self.folds.pop()[:max(self._pub_n, 1)]
        self._progs: Dict[str, Dict] = {}
        self._round = 0
        self._plan_seed = cfg.seed * 100_003 + 29
        self.history = HeteroHistory()

    # -- per-arch jitted programs -----------------------------------------
    def _prog(self, arch: str) -> Dict:
        if arch in self._progs:
            return self._progs[arch]
        cm = self._models[arch]
        opt_cfg = self.opt_cfg
        kl_w = self.cfg.kl_weight

        @jax.jit
        def local_scan(params, opt, inputs, labs, keys):
            """One client's whole local phase: scan over its (T, B) plan."""
            def body(carry, xs):
                p, o = carry
                inp, la, k = xs
                loss, grads = jax.value_and_grad(
                    lambda q: cm.private_loss(q, inp, la, k))(p)
                p2, o2, _ = adamw_update(p, grads, o, opt_cfg)
                return (p2, o2), loss
            (params, opt), losses = jax.lax.scan(body, (params, opt),
                                                 (inputs, labs, keys))
            return params, opt, jnp.mean(losses)

        @jax.jit
        def mutual_step(params, opt, inputs, labs, others_logits, key):
            """Eq. 1 with the received logits fixed (one mutual epoch)."""
            def loss_fn(p):
                ce, live = cm.public_ce_and_logits(p, inputs, labs, key)
                kl = jnp.mean(kl_to_received(live, others_logits))
                return ce + kl_w * kl, (ce, kl)
            (_, (ce, kl)), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            params, opt, _ = adamw_update(params, grads, opt, opt_cfg)
            return params, opt, ce, kl

        share = jax.jit(cm.share_logits)
        eval_ce = jax.jit(
            lambda p, x, y: cm.public_ce_and_logits(p, x, y, None)[0])
        self._progs[arch] = {"local": local_scan, "mutual": mutual_step,
                             "share": share, "eval_ce": eval_ce}
        return self._progs[arch]

    # -- helpers ----------------------------------------------------------
    def _round_key(self, r: int) -> jax.Array:
        return jax.random.fold_in(self.base_key, r)

    def participants(self, r: int) -> List[int]:
        """The M clients sampled for round r (stateless in r — resume-safe)."""
        return sample_participants(self.cfg.n_clients, self.cfg.participation,
                                   self.cfg.seed, r)

    def _gather(self, idx: np.ndarray):
        return jnp.asarray(self.data[idx]), jnp.asarray(self.labels[idx])

    # -- rounds -----------------------------------------------------------
    def run(self, until: int = 0) -> HeteroHistory:
        """Run rounds up to ``until`` (0 -> cfg.rounds).  Picks up from the
        current round counter, so save_state/restore_state mid-run and a
        second ``run()`` continue exactly where the checkpoint left off."""
        stop = until or self.cfg.rounds
        for r in range(self._round, min(stop, self.cfg.rounds)):
            self._run_round(r)
        return self.history

    def _run_round(self, r: int):
        cfg = self.cfg
        K = cfg.n_clients
        part = self.participants(r)
        key_r = self._round_key(r)
        self._plan_seed += 1
        # 1) local phase — K folds popped in Algorithm-1 order regardless of
        # participation (the fold budget is part of the protocol); the
        # absentees' folds go unused this round
        folds = [self.folds.pop() for _ in range(K)]
        local_losses = [0.0] * K
        for c in part:
            idx, _ = round_batch_indices([folds[c]], cfg.local_epochs,
                                         cfg.batch_size,
                                         seed=self._plan_seed * K + c)
            idx = idx[0, :self._local_T]            # fixed T: stable jit cache
            if idx.shape[0] == 0:
                continue
            inputs, labs = self._gather(idx)
            keys = jax.random.split(jax.random.fold_in(key_r, 100 + c),
                                    idx.shape[0])
            prog = self._prog(cfg.archs[c])
            self.client_params[c], self.client_opts[c], loss = prog["local"](
                self.client_params[c], self.client_opts[c], inputs, labs, keys)
            local_losses[c] = float(loss)
        # 2) mutual phase on the rotating public fold
        pub = self.folds.pop()[:self._pub_n]
        pub_inputs, pub_labs = self._gather(pub)
        public_ce = [0.0] * K
        kl_losses = [0.0] * K
        comm = 0
        if cfg.mutual_epochs > 0 and len(part) >= 2:
            n_pub = None
            for e in range(cfg.mutual_epochs):
                # every participant publishes; ONLY these logits cross
                # client boundaries
                shared = [np.asarray(self._prog(cfg.archs[c])["share"](
                    self.client_params[c], pub_inputs)) for c in part]
                stack = np.stack(shared)            # (M, N_pub, V)
                n_pub = stack.shape[1]
                for s, c in enumerate(part):
                    others = jnp.asarray(np.delete(stack, s, axis=0))
                    k = jax.random.fold_in(key_r, 1000 + e * K + c)
                    prog = self._prog(cfg.archs[c])
                    (self.client_params[c], self.client_opts[c],
                     ce, kl) = prog["mutual"](
                        self.client_params[c], self.client_opts[c],
                        pub_inputs, pub_labs, others, k)
                    public_ce[c] = float(ce)
                    kl_losses[c] = float(kl)
            comm = comm_bytes_per_round(len(part), n_pub, self.n_classes,
                                        cfg.mutual_epochs)["round"]
        self.history.total_comm_bytes += comm
        self.history.rounds.append(HeteroRoundLog(
            r, part, local_losses, public_ce, kl_losses, comm))
        self._round = r + 1

    # -- eval -------------------------------------------------------------
    def evaluate(self) -> HeteroHistory:
        """Per-client model loss on the common held-out fold (comparable
        across families — it is the same public-style CE every client
        optimises in Eq. 1)."""
        inputs, labs = self._gather(self.eval_fold)
        self.history.client_eval_loss = [
            float(self._prog(a)["eval_ce"](p, inputs, labs))
            for a, p in zip(self.cfg.archs, self.client_params)]
        return self.history

    # -- checkpoint/resume ------------------------------------------------
    def save_state(self, path: str) -> None:
        """Full federated state: per-client params + opt + round counter."""
        state = {"clients": [{"params": p, "opt": o} for p, o in
                             zip(self.client_params, self.client_opts)]}
        meta = {
            "engine": "hetero",
            "archs": list(self.cfg.archs),
            "n_rounds": self.cfg.rounds,
            "pool_n": len(self.labels),
            "round": self._round,
            "plan_seed": self._plan_seed,
            "scheduler": self.folds.state(),
            "total_comm_bytes": self.history.total_comm_bytes,
            "rounds": [asdict(rl) for rl in self.history.rounds],
        }
        checkpoint.save(path, state, meta)

    def restore_state(self, path: str) -> None:
        """Load a ``save_state`` checkpoint into this trainer (must be
        constructed with the same config and data pool)."""
        state, meta = checkpoint.restore(path)
        if meta.get("archs") != list(self.cfg.archs):
            raise ValueError(f"checkpoint archs {meta.get('archs')} != "
                             f"config archs {list(self.cfg.archs)}")
        # the fold PARTITION is deterministic in (labels, K, rounds, seed):
        # a different round schedule or pool silently re-partitions the
        # data, so the restored cursor would index folds the checkpointed
        # run never saw — refuse instead of resuming on the wrong folds
        if meta.get("n_rounds", self.cfg.rounds) != self.cfg.rounds or \
                meta.get("pool_n", len(self.labels)) != len(self.labels):
            raise ValueError(
                f"checkpoint schedule (rounds={meta.get('n_rounds')}, "
                f"pool={meta.get('pool_n')}) != config "
                f"(rounds={self.cfg.rounds}, pool={len(self.labels)}); "
                "resume needs the same fold partition — save with the full "
                "round budget and stop early via run(until=...)")
        self.client_params = [c["params"] for c in state["clients"]]
        self.client_opts = [c["opt"] for c in state["clients"]]
        self._round = int(meta["round"])
        self._plan_seed = int(meta["plan_seed"])
        self.folds.load_state(meta["scheduler"])
        self.history = HeteroHistory(
            rounds=[HeteroRoundLog(**d) for d in meta.get("rounds", [])],
            total_comm_bytes=int(meta.get("total_comm_bytes", 0)))
