"""Mesh-scale federated mutual learning — the paper's technique as a
first-class distributed-training feature.

Clients are a leading K axis on every param/opt leaf, sharded over the
``client`` logical axis (physically: the ``pod`` mesh axis in multi-pod
mode).  The per-client step is vmapped; cross-client interaction happens
ONLY in the Eq.-2 term, where the public-batch logits (K, B_pub*S, V) are
all-gathered over the client axis — bytes independent of model size, which
is the paper's bandwidth claim made literal on the mesh.

Provided steps (each individually jit/lower-able for the dry-run):
  - local_train_step:  vmapped per-client CE training on private shards
  - mutual_step:       Eq. 1 on the rotating public batch (DML sharing+update)
  - dml_train_step:    local + mutual fused (one program)
  - fedavg_sync:       all-reduce(params)/K over the client axis (baseline #1)
  - async_sync:        metric-weighted partial sync (baseline #2)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import sharding
from repro.configs.base import ModelConfig
from repro.core import stacking
from repro.core.async_fl import layer_schedule
from repro.core.mutual import (_pair_mask, mutual_kl_loss,
                               sparse_mutual_kl_loss, topk_predictions)
from repro.kernels import ops
from repro.models import transformer as tfm
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, global_norm)
from repro.sharding import constrain

Params = Any


# ---------------------------------------------------------------------------
# init

def stacked_init(key, cfg: ModelConfig, n_clients: int) -> Params:
    return stacking.stacked_init(key, lambda k: tfm.init_model(k, cfg),
                                 n_clients)


def stacked_adamw_init(stacked_params: Params) -> Dict:
    """AdamW state over the stacked params; the scalar step is shared across
    clients (one LR schedule for the whole fleet)."""
    return adamw_init(stacked_params)


def stacked_logical_axes(cfg: ModelConfig) -> Params:
    ax = tfm.logical_axes(cfg)
    return jax.tree.map(
        lambda t: ("client",) + t, ax,
        is_leaf=lambda x: isinstance(x, tuple) and not isinstance(x, dict))


# ---------------------------------------------------------------------------
# steps

def _cvmap(spmd_axis_name=None):
    """vmap over the client axis; ``spmd_axis_name`` pins the vmapped dim's
    sharding for every constraint inside (without it, SPMD may replicate
    per-client activations across pods — measured 1 GiB/layer of pod-axis
    K/V all-gathers in the mutual step)."""
    def wrap(fn):
        if spmd_axis_name:
            return jax.vmap(fn, spmd_axis_name=spmd_axis_name)
        return jax.vmap(fn)
    return wrap


def make_local_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                          remat: bool = True, unroll: bool = False,
                          spmd_client_axis=None, impl=None):
    """Vmapped private-shard CE step.

    batch: tokens (K, B, S_tok) [+ prefix (K, B, P, pd)].

    ``part_mask`` (K,) 0/1: absentees' losses are zeroed BEFORE the grad,
    so their private data contributes nothing — not even through the
    shared global-norm gradient clip — and their params/opt ride through
    unchanged (the same pre-grad weighting the fused DML step uses).
    """
    def step(stacked_params, opt_state, tokens, prefix=None,
             part_mask=None):
        def total_loss(sp):
            if prefix is None:
                losses, metrics = _cvmap(spmd_axis_name=spmd_client_axis)(
                    lambda p, t: tfm.loss_fn(p, cfg, t, remat=remat,
                                             unroll=unroll, impl=impl)
                )(sp, tokens)
            else:
                losses, metrics = _cvmap(spmd_axis_name=spmd_client_axis)(
                    lambda p, t, pe: tfm.loss_fn(p, cfg, t, pe, remat=remat,
                                                 unroll=unroll, impl=impl)
                )(sp, tokens, prefix)
            pm = 1.0 if part_mask is None else jnp.asarray(part_mask,
                                                           jnp.float32)
            return jnp.sum(losses * pm), metrics
        (_, metrics), grads = jax.value_and_grad(total_loss, has_aux=True)(
            stacked_params)
        new_params, new_opt, om = adamw_update(stacked_params, grads,
                                               opt_state, opt_cfg)
        if part_mask is not None:
            new_params, new_opt = _mask_participation(
                stacked_params, opt_state, new_params, new_opt, part_mask)
        return new_params, new_opt, {**metrics, **om}
    return step


def _mutual_term(flat, temperature, sparse_k, part_mask=None, impl=None):
    """Eq. 2 term: dense (full logits gathered) or sparse top-k sharing.

    ``impl`` routes both variants through the fused streaming kernels
    (``ops.mutual_kl_pair`` / ``ops.sparse_mutual_kl``) on kernel impls.
    """
    if sparse_k:
        assert part_mask is None, \
            "sparse top-k sharing + partial participation not supported yet"
        idx, logp_top = topk_predictions(
            jax.lax.stop_gradient(flat), sparse_k, temperature)
        return sparse_mutual_kl_loss(flat, idx, logp_top, temperature,
                                     impl=impl)
    return mutual_kl_loss(flat, temperature, part_mask=part_mask, impl=impl)


def make_mutual_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                     kl_weight: float = 1.0, temperature: float = 1.0,
                     remat: bool = True, ce_weight: float = 1.0,
                     unroll: bool = False, sparse_k: int = 0,
                     spmd_client_axis=None, impl=None):
    """Eq. 1 on the public batch: CE(public) + kl_weight * KLD_avg.

    public tokens: (B_pub, S_tok) — same data for every client (that is the
    point); per-client logits differ because params differ.

    ``part_mask`` (K,) 0/1 enables partial participation: absentees are
    masked out of the Eq.-2 average and their params/opt pass through
    unchanged (the AdamW schedule step is shared fleet-wide and still
    advances).
    """
    def step(stacked_params, opt_state, public_tokens, public_prefix=None,
             part_mask=None):
        def total_loss(sp):
            if public_prefix is None:
                losses, fwd = _cvmap(spmd_axis_name=spmd_client_axis)(
                    lambda p: _public_ce_and_logits(p, cfg, public_tokens,
                                                    None, remat, unroll,
                                                    impl))(sp)
            else:
                losses, fwd = _cvmap(spmd_axis_name=spmd_client_axis)(
                    lambda p: _public_ce_and_logits(p, cfg, public_tokens,
                                                    public_prefix, remat,
                                                    unroll, impl))(sp)
            K, B, S, V = fwd.shape
            flat = constrain(fwd.reshape(K, B * S, V), "client", None, "vocab")
            kl = _mutual_term(flat, temperature, sparse_k, part_mask,
                              impl=impl)  # (K,)
            pm = 1.0 if part_mask is None else jnp.asarray(part_mask,
                                                           jnp.float32)
            total = (ce_weight * jnp.sum(losses * pm)
                     + kl_weight * jnp.sum(kl))
            return total, {"public_ce": losses, "kld_avg": kl}
        (_, metrics), grads = jax.value_and_grad(total_loss, has_aux=True)(
            stacked_params)
        new_params, new_opt, om = adamw_update(stacked_params, grads,
                                               opt_state, opt_cfg)
        if part_mask is not None:
            new_params, new_opt = _mask_participation(
                stacked_params, opt_state, new_params, new_opt, part_mask)
        return new_params, new_opt, {**metrics, **om}
    return step


def _public_ce_and_logits(params, cfg, tokens, prefix, remat, unroll=False,
                          impl=None):
    logits, _ = tfm.forward(params, cfg, tokens, prefix, remat=remat,
                            unroll=unroll, impl=impl)
    P = cfg.prefix_tokens or 0
    if P:
        pred, labels = logits[:, P - 1: -1], tokens
    else:
        pred, labels = logits[:, :-1], tokens[:, 1:]
    logp = jax.nn.log_softmax(pred.astype(jnp.float32), axis=-1)
    ce = -jnp.mean(jnp.take_along_axis(logp, labels[..., None], -1))
    # mutual KL acts on the token-position logits (prefix stripped)
    return ce, logits[:, P:] if P else logits


def _mask_participation(old_params, old_opt, new_params, new_opt, part_mask):
    """Absent clients keep params and AdamW moments; the (shared, scalar)
    schedule step keeps advancing."""
    params = stacking.client_lerp(old_params, new_params, part_mask)
    opt = {"mu": stacking.client_lerp(old_opt["mu"], new_opt["mu"], part_mask),
           "nu": stacking.client_lerp(old_opt["nu"], new_opt["nu"], part_mask),
           "step": new_opt["step"]}
    return params, opt


def make_dml_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                        kl_weight: float = 1.0, temperature: float = 1.0,
                        remat: bool = True, unroll: bool = False,
                        sparse_k: int = 0, spmd_client_axis=None,
                        impl=None):
    """One fused DML round-step: private CE + Eq. 1 on the public batch.

    ``part_mask`` (K,) 0/1 enables partial participation (see
    ``make_mutual_step``).  ``impl`` is the kernel implementation the
    population resolved at construction — threaded into BOTH the mixer
    forward (``tfm.loss_fn``; the attention/SSD kernels carry custom VJPs,
    so the same impl runs forward and backward) and the Eq.-2 term, never
    read from ambient state inside the jitted step."""
    def step(stacked_params, opt_state, tokens, public_tokens,
             prefix=None, public_prefix=None, part_mask=None):
        def total_loss(sp):
            if prefix is None:
                priv, pm = _cvmap(spmd_axis_name=spmd_client_axis)(
                    lambda p, t: tfm.loss_fn(p, cfg, t, remat=remat,
                                             unroll=unroll, impl=impl)
                )(sp, tokens)
                ce_pub, fwd = _cvmap(spmd_axis_name=spmd_client_axis)(
                    lambda p: _public_ce_and_logits(p, cfg, public_tokens,
                                                    None, remat, unroll,
                                                    impl))(sp)
            else:
                priv, pm = _cvmap(spmd_axis_name=spmd_client_axis)(
                    lambda p, t, pe: tfm.loss_fn(p, cfg, t, pe, remat=remat,
                                                 unroll=unroll, impl=impl)
                )(sp, tokens, prefix)
                ce_pub, fwd = _cvmap(spmd_axis_name=spmd_client_axis)(
                    lambda p: _public_ce_and_logits(p, cfg, public_tokens,
                                                    public_prefix, remat,
                                                    unroll, impl))(sp)
            K, B, S, V = fwd.shape
            flat = constrain(fwd.reshape(K, B * S, V), "client", None, "vocab")
            kl = _mutual_term(flat, temperature, sparse_k, part_mask,
                              impl=impl)
            w = 1.0 if part_mask is None else jnp.asarray(part_mask,
                                                          jnp.float32)
            total = (jnp.sum(priv * w) + jnp.sum(ce_pub * w)
                     + kl_weight * jnp.sum(kl))
            return total, {"private_loss": priv, "public_ce": ce_pub,
                           "kld_avg": kl}
        (_, metrics), grads = jax.value_and_grad(total_loss, has_aux=True)(
            stacked_params)
        new_params, new_opt, om = adamw_update(stacked_params, grads,
                                               opt_state, opt_cfg)
        if part_mask is not None:
            new_params, new_opt = _mask_participation(
                stacked_params, opt_state, new_params, new_opt, part_mask)
        return new_params, new_opt, {**metrics, **om}
    return step


def make_sharded_dml_step(cfg: ModelConfig, opt_cfg: AdamWConfig, mesh,
                          n_clients: int, kl_weight: float = 1.0,
                          temperature: float = 1.0, remat: bool = True,
                          unroll: bool = False, impl: Optional[str] = None):
    """``make_dml_train_step`` device-sharded over a ``clients`` mesh axis.

    Each device owns whole clients (round-robin spill for
    n_clients > n_devices via ``stacking.client_layout``); private-shard CE
    runs collective-free, and the ONLY cross-device traffic is one
    all-gather of the public-batch logits (K_loc, B_pub*S, V) feeding the
    Eq.-2 term — the paper's communication frontier as real collective
    traffic (``comm_bytes``'s ``dml_round`` simulates exactly these bytes).

    Two deliberate deltas vs the unsharded step:
      - grad clipping is per client (``clip_norm`` applies to each client's
        own gradient) — the unsharded step's fleet-wide global norm would
        couple clients and need a second collective;
      - the Eq.-2 term goes through ``ops.mutual_kl_pair`` (``impl`` as in
        ``kernels.ops``), i.e. the fused streaming kernel + custom-VJP
        blocked backward on kernel impls.

    Prefix-conditioned archs (``cfg.prefix_tokens``) are not supported.
    Returns ``step(stacked_params, opt_state, tokens, public_tokens,
    part_mask=None)``; jit the result.
    """
    if cfg.prefix_tokens:
        raise ValueError("sharded DML step: prefix-conditioned archs are "
                         "not supported yet")
    n_dev = mesh.shape[stacking.CLIENT_AXIS]
    k_loc, k_pad = stacking.client_layout(n_clients, n_dev)
    spec = stacking.client_spec()
    opt_noclip = dataclasses.replace(opt_cfg, clip_norm=None)

    def body(params, opt, tokens, public_tokens, pm_full):
        gids = stacking.local_client_ids(n_clients, n_dev)
        pm_loc = jnp.take(pm_full, gids)
        pair_w = jnp.take(_pair_mask(k_pad, pm_full), gids, axis=0)

        def total_loss(sp):
            priv, _ = jax.vmap(
                lambda p, t: tfm.loss_fn(p, cfg, t, remat=remat,
                                         unroll=unroll,
                                         impl=impl))(sp, tokens)
            ce_pub, fwd = jax.vmap(
                lambda p: _public_ce_and_logits(p, cfg, public_tokens,
                                                None, remat, unroll,
                                                impl))(sp)
            K_l, B, S, V = fwd.shape
            flat = fwd.reshape(K_l, B * S, V)
            gathered = stacking.gather_clients(
                jax.lax.stop_gradient(flat), n_clients, n_dev)
            kl = jnp.mean(ops.mutual_kl_pair(
                flat, gathered, pair_w, temperature=temperature,
                impl=impl), axis=-1)                          # (K_loc,)
            total = (jnp.sum(priv * pm_loc) + jnp.sum(ce_pub * pm_loc)
                     + kl_weight * jnp.sum(kl))
            return total, {"private_loss": priv, "public_ce": ce_pub,
                           "kld_avg": kl}

        (_, metrics), grads = jax.value_and_grad(total_loss, has_aux=True)(
            params)
        if opt_cfg.clip_norm is not None:
            grads, gnorm = jax.vmap(
                lambda g: clip_by_global_norm(g, opt_cfg.clip_norm))(grads)
        else:
            gnorm = jax.vmap(global_norm)(grads)
        new_params, new_opt, om = adamw_update(params, grads, opt,
                                               opt_noclip)
        new_params, new_opt = _mask_participation(params, opt, new_params,
                                                  new_opt, pm_loc)
        return new_params, new_opt, {**metrics, "grad_norm": gnorm,
                                     "lr": om["lr"]}

    opt_spec = {"mu": spec, "nu": spec, "step": P()}
    met_spec = {"private_loss": spec, "public_ce": spec, "kld_avg": spec,
                "grad_norm": spec, "lr": P()}
    run = sharding.shard_map(
        body, mesh,
        in_specs=(spec, opt_spec, spec, P(), P()),
        out_specs=(spec, opt_spec, met_spec))

    def step(stacked_params, opt_state, tokens, public_tokens,
             part_mask=None):
        pm = jnp.ones((n_clients,), jnp.float32) if part_mask is None \
            else jnp.asarray(part_mask, jnp.float32)
        pm_nat = jnp.zeros((k_pad,), jnp.float32).at[:n_clients].set(pm)
        shard = lambda t: stacking.shard_clients(t, n_clients, n_dev)
        new_p, new_o, met = run(
            shard(stacked_params),
            {"mu": shard(opt_state["mu"]), "nu": shard(opt_state["nu"]),
             "step": opt_state["step"]},
            shard(tokens), public_tokens, pm_nat)
        unshard = lambda t: stacking.unshard_clients(t, n_clients, n_dev)
        met = {k: (unshard(v) if k != "lr" else v) for k, v in met.items()}
        return unshard(new_p), \
            {"mu": unshard(new_o["mu"]), "nu": unshard(new_o["nu"]),
             "step": new_o["step"]}, met

    return step


# ---------------------------------------------------------------------------
# weight-sharing baselines on the client axis

def fedavg_sync(stacked_params: Params, part_mask=None) -> Params:
    """All-reduce(params)/K over the client axis (vanilla FL round).

    With ``part_mask`` (K,) 0/1, only participants are averaged and only
    participants receive the aggregate back (absentees are offline)."""
    if part_mask is None:
        def avg(p):
            m = jnp.mean(p.astype(jnp.float32), axis=0, keepdims=True)
            return jnp.broadcast_to(m, p.shape).astype(p.dtype)
        return jax.tree.map(avg, stacked_params)
    from repro.core.fedavg import weighted_average_weights
    avg = weighted_average_weights(stacked_params, part_mask)
    return stacking.client_lerp(stacked_params, avg, part_mask)


def transformer_shallow_mask(cfg: ModelConfig, stacked_params: Params):
    """Float lerp-mask: embed/projector + first half of the periods are
    'shallow' (synced every round); the rest is 'deep'."""
    half = cfg.n_periods // 2

    def mask_like(path, p):
        names = [str(getattr(q, "key", getattr(q, "name", q))) for q in path]
        if "periods" in names:
            per = jnp.arange(cfg.n_periods, dtype=jnp.float32) < half
            return per.reshape((1, cfg.n_periods) + (1,) * (p.ndim - 2))
        if "embed" in names or "projector" in names:
            return jnp.ones((1,) * p.ndim, jnp.float32)
        return jnp.zeros((1,) * p.ndim, jnp.float32)

    return jax.tree_util.tree_map_with_path(mask_like, stacked_params)


def async_sync(stacked_params: Params, scores, shallow_mask,
               round_idx: int, delta: int = 3, min_round: int = 5) -> Params:
    """Metric-weighted partial sync (async baseline) on the client axis."""
    layer = layer_schedule(round_idx, delta, min_round)
    w = jnp.asarray(scores, jnp.float32)
    w = w / jnp.maximum(jnp.sum(w), 1e-9)

    def sync(p, m):
        pf = p.astype(jnp.float32)
        wb = w.reshape((-1,) + (1,) * (p.ndim - 1))
        avg = jnp.broadcast_to(jnp.sum(pf * wb, axis=0, keepdims=True), p.shape)
        lerp = m if layer == "shallow" else 1.0 - m
        return (pf * (1 - lerp) + avg * lerp).astype(p.dtype)

    return jax.tree.map(sync, stacked_params, shallow_mask)


# ---------------------------------------------------------------------------
# communication accounting (analytic; HLO-parsed numbers live in benchmarks)

def comm_bytes(cfg: ModelConfig, n_clients: int, public_tokens: int,
               bytes_per_el: int = 2) -> Dict[str, int]:
    n = cfg.param_count()
    return {
        "fedavg_round": 2 * n_clients * n * bytes_per_el,
        "dml_round": 2 * n_clients * public_tokens * cfg.vocab_size * bytes_per_el,
        "ratio": (n / max(public_tokens * cfg.vocab_size, 1)),
    }
