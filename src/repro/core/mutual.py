"""Deep mutual learning losses — the paper's Eq. 1 and Eq. 2.

    Loss_i    = ModelLoss_i + KLD_avg_i                       (Eq. 1)
    KLD_avg_i = 1/(K-1) * sum_{j != i} KL(P_i || P_j)         (Eq. 2)

Two gradient semantics:
  - ``mutual_kl_terms(live, fixed)``: the *federated* semantics — each client
    descends its own loss with the received predictions held constant
    (``fixed`` should be stop_gradient'ed).  Used inside train steps.
  - ``ops.mutual_kl``: forward-only fused kernel — the sharing/eval hot path
    (what actually gets computed on the public set and broadcast).

Categorical KL over the vocab for LLMs; Bernoulli KL for the paper's
sigmoid VisionNet head.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _pair_mask(K: int, part_mask):
    """(K, K) pair weights for the Eq.-2 average under partial participation.

    ``part_mask`` is a (K,) 0/1 participation vector (None -> everyone).
    Row i is zeroed when client i sits the round out; column j is excluded
    from every average when client j shared nothing; the 1/(K-1) denominator
    shrinks to 1/(M-1) where M = number of participants.
    """
    eye = jnp.eye(K, dtype=jnp.float32)
    if part_mask is None:
        return (1.0 - eye) / max(K - 1, 1)
    m = jnp.asarray(part_mask, jnp.float32)
    pair = m[:, None] * m[None, :] * (1.0 - eye)
    denom = jnp.maximum(jnp.sum(m) - 1.0, 1.0)
    return pair / denom


def mutual_kl_terms_vs(live_logits, fixed_logits, pair_w,
                       temperature: float = 1.0):
    """Rectangular Eq. 2: (Kl, B, V) live x (Kg, B, V) fixed -> (Kl, B).

    out[i, b] = sum_j pair_w[i, j] * KL(softmax(live_i) || softmax(fixed_j))
    with explicit (Kl, Kg) pair weights.  This is the device-local shard of
    ``mutual_kl_terms``: rows are this device's clients, columns the
    all-gathered fleet (``stacking.gather_clients``), and ``pair_w`` the
    matching rows of ``_pair_mask``.  The math IS the kernel oracle.
    """
    return ref.mutual_kl_pair(live_logits, fixed_logits, pair_w,
                              temperature=temperature)


def mutual_kl_terms(live_logits, fixed_logits, temperature: float = 1.0,
                    part_mask=None, impl=None):
    """Eq. 2 with the j-side fixed.  (K, B, V) x (K, B, V) -> (K, B).

    out[i, b] = 1/(K-1) sum_{j != i} KL(softmax(live_i) || softmax(fixed_j)).
    Pass ``fixed_logits = jax.lax.stop_gradient(live_logits)`` for the
    federated gradient semantics (others' predictions are received data).
    ``part_mask`` (K,) 0/1 drops non-participants from both sides of the
    average (partial participation: M <= K clients per round).

    ``impl`` (default: ``ops.get_impl()``): 'ref' keeps the plain-JAX graph
    (AD-derived gradients); 'interpret'/'pallas' route through the fused
    streaming kernel with its custom-VJP blocked backward
    (``ops.mutual_kl_pair``) — the Eq.-2 TRAINING hot path at vocab scale.
    """
    K = live_logits.shape[0]
    impl = impl or ops.get_impl()
    pair_w = _pair_mask(K, part_mask)
    if impl != "ref":
        return ops.mutual_kl_pair(live_logits, fixed_logits, pair_w,
                                  temperature=temperature, impl=impl)
    return mutual_kl_terms_vs(live_logits, fixed_logits, pair_w,
                              temperature=temperature)


def mutual_kl_loss(all_logits, temperature: float = 1.0,
                   stop_grad_others: bool = True, part_mask=None,
                   impl=None):
    """Per-client mean Eq.-2 loss from a live stacked logits tensor.

    all_logits: (K, B, V) (flatten (B, S) upstream).  Returns (K,) scalars.
    ``impl`` routes the Eq.-2 term through the fused streaming kernel
    (see ``mutual_kl_terms``).
    """
    fixed = jax.lax.stop_gradient(all_logits) if stop_grad_others else all_logits
    terms = mutual_kl_terms(all_logits, fixed, temperature,
                            part_mask=part_mask, impl=impl)
    return jnp.mean(terms, axis=-1)


def kl_to_received(live_logits, received_logits, temperature: float = 1.0):
    """Eq. 2 for ONE client against the predictions it received.

    live_logits: (B, V) — local, differentiable.
    received_logits: (J, B, V) — the J other participants' shared logits
    (treated as constants; stop_gradient applied here).

    Returns (B,) = 1/J * sum_j KL(softmax(live) || softmax(received_j)).
    The heterogeneous engine uses this: clients with different pytrees
    cannot be stacked, so each computes its own Eq.-2 term against the
    logits tensor that actually crossed the client boundary.
    """
    rec = jax.lax.stop_gradient(received_logits.astype(jnp.float32))
    lp_live = jax.nn.log_softmax(
        live_logits.astype(jnp.float32) / temperature, axis=-1)
    p_live = jnp.exp(lp_live)
    lp_rec = jax.nn.log_softmax(rec / temperature, axis=-1)  # (J,B,V)
    self_term = jnp.sum(p_live * lp_live, axis=-1)           # (B,)
    cross = jnp.einsum("bv,jbv->jb", p_live, lp_rec)         # (J,B)
    J = received_logits.shape[0]
    return self_term - jnp.sum(cross, axis=0) / max(J, 1)


def mutual_kl_eval(all_logits, temperature: float = 1.0, impl=None):
    """Forward-only Eq. 2 via the fused kernel (sharing/benchmark path)."""
    return ops.mutual_kl(all_logits, temperature=temperature, impl=impl)


# ---------------------------------------------------------------------------
# sparse (top-k) prediction sharing — beyond-paper bandwidth optimisation.
# Clients publish only (indices, log-probs) of their top-k tokens; the
# receiver treats the residual mass as uniform over the tail.  Cross-client
# bytes drop by V/k (e.g. 152064/64 ≈ 2400x) at a small KL approximation
# error.  See EXPERIMENTS.md §Perf.

def _distributed_topk(logp, k: int):
    """Two-stage top-k that never gathers the vocab axis.

    XLA's SPMD partitioning of sort/top_k REPLICATES every non-sort dim
    (measured: the full (K, B, V) logits all-gathered across pods).  We
    instead shard_map: local top-k per vocab shard, all-gather only the
    k·n_shards candidates (tiny), then a final local top-k.  Falls back to
    plain top_k when there is no mesh / no sharded vocab axis.
    """
    from jax.sharding import PartitionSpec as P
    from repro.sharding import current_mesh, get_rules, shard_map
    mesh = current_mesh()
    if mesh is None:
        return jax.lax.top_k(logp, k)
    rules = get_rules()
    vocab_ax = rules.get("vocab")
    client_ax = rules.get("client")
    axes = mesh.axis_names
    if isinstance(client_ax, tuple):      # e.g. ("clients", "pod")
        client_ax = next((a for a in client_ax if a in axes), None)
    vocab_ax = vocab_ax if vocab_ax in axes else None
    client_ax = client_ax if (client_ax in axes and
                              logp.shape[0] % mesh.shape[client_ax] == 0) \
        else None
    if vocab_ax is None or logp.shape[-1] % mesh.shape[vocab_ax] != 0:
        return jax.lax.top_k(logp, k)

    def local(lp):                             # (K_loc, B, V_loc)
        v, i = jax.lax.top_k(lp, min(k, lp.shape[-1]))
        i = i + jax.lax.axis_index(vocab_ax) * lp.shape[-1]
        vg = jax.lax.all_gather(v, vocab_ax, axis=-1, tiled=True)
        ig = jax.lax.all_gather(i, vocab_ax, axis=-1, tiled=True)
        vv, sel = jax.lax.top_k(vg, k)
        return jnp.take_along_axis(ig, sel, axis=-1), vv

    spec_in = P(client_ax, *([None] * (logp.ndim - 2)), vocab_ax)
    spec_out = P(client_ax, *([None] * (logp.ndim - 1)))
    idx, vals = shard_map(local, mesh=mesh, in_specs=(spec_in,),
                          out_specs=(spec_out, spec_out))(logp)
    return vals, idx


def topk_predictions(logits, k: int, temperature: float = 1.0):
    """What a client publishes: (indices (..., k), log-probs (..., k))."""
    from repro.sharding import constrain
    lf = logits.astype(jnp.float32) / temperature
    logp = jax.nn.log_softmax(lf, axis=-1)
    vals, idx = _distributed_topk(logp, k)
    tail = (None,) * (logits.ndim - 1)
    return (constrain(idx, "client", *tail),
            constrain(vals, "client", *tail))


def sparse_mutual_kl_loss(live_logits, idx, logp_top,
                          temperature: float = 1.0, impl=None):
    """Eq. 2 against RECEIVED sparse predictions.

    live_logits: (K, B, V) — local, differentiable.
    idx, logp_top: (K, B, k) — received top-k sets (treated as constants).

    KL(P_i || ~P_j) with ~P_j = top-k of P_j + uniform tail:
        KL_ij = -H(P_i) - c_j (1 - s_ij) - sum_t p_i[idx_j,t] logp_j[t]
    where s_ij = sum_t p_i[idx_j,t] and c_j = log(residual_j / (V - k)).
    Returns (K,) per-client means over B.

    ``impl`` (default: ``ops.get_impl()``): 'ref' keeps the plain-JAX graph
    below with its explicit SPMD sharding constraints (AD-derived
    gradients); kernel impls route through the fused top-k-gather +
    streaming-softmax Pallas kernel (``ops.sparse_mutual_kl``) whose
    custom-VJP backward streams over vocab blocks — per-round FLOPs/HBM
    traffic then scale with k, not V.
    """
    K, B, V = live_logits.shape
    k = idx.shape[-1]
    impl = impl or ops.get_impl()
    idx = jax.lax.stop_gradient(idx)
    logp_top = jax.lax.stop_gradient(logp_top.astype(jnp.float32))
    if impl != "ref":
        pair_w = (1.0 - jnp.eye(K, dtype=jnp.float32)) / max(K - 1, 1)
        terms = ops.sparse_mutual_kl(live_logits, idx, logp_top, pair_w,
                                     temperature=temperature, impl=impl)
        return jnp.mean(terms, axis=-1)
    lp_live = jax.nn.log_softmax(
        live_logits.astype(jnp.float32) / temperature, axis=-1)
    p_live = jnp.exp(lp_live)                            # (K,B,V)
    neg_h = jnp.sum(p_live * lp_live, axis=-1)           # (K,B)

    residual = jnp.clip(1.0 - jnp.sum(jnp.exp(logp_top), axis=-1),
                        1e-9, 1.0)                       # (K,B)
    c = jnp.log(residual / max(V - k, 1))                # (K,B)

    # pairwise gather WITHOUT materialising a (K, K, B, V) operand: loop the
    # (small, static) j axis; each step gathers only (K, B, k) values.  The
    # broadcast of client j's indices must be re-constrained to the client
    # axis or SPMD un-shards K and all-gathers p_live across pods (measured:
    # 98 GiB/device — see EXPERIMENTS.md §Perf pick 3).
    from repro.sharding import constrain
    p_ats = []
    for j in range(K):
        idx_j = jnp.broadcast_to(idx[j][None], (K, B, k))
        idx_j = constrain(idx_j, "client", None, None)
        p_at_j = jnp.take_along_axis(p_live, idx_j, axis=-1)
        p_ats.append(constrain(p_at_j, "client", None, None))
    p_at = jnp.stack(p_ats, axis=1)                      # (i,j,B,k)
    p_at = constrain(p_at, "client", None, None, None)
    s = jnp.sum(p_at, axis=-1)                           # (i,j,B)
    cross_top = jnp.sum(p_at * logp_top[None], axis=-1)  # (i,j,B)
    kl = neg_h[:, None, :] - c[None] * (1.0 - s) - cross_top
    mask = (1.0 - jnp.eye(K))[:, :, None]
    terms = jnp.sum(kl * mask, axis=1) / max(K - 1, 1)   # (K,B)
    return jnp.mean(terms, axis=-1)


def sparse_kl_to_received(live_logits, idx, logp_top,
                          temperature: float = 1.0, impl=None):
    """Eq. 2 for ONE client against RECEIVED sparse (top-k) predictions.

    live_logits: (B, V) — local, differentiable.
    idx, logp_top: (J, B, k) — the J other participants' top-k sets
    (treated as constants; stop_gradient applied here).

    Same tail model as ``sparse_mutual_kl_loss`` (~P_j = top-k mass +
    uniform residual over the V-k tail):
        KL_j = -H(P_i) - c_j (1 - s_j) - sum_t p_i[idx_j,t] logp_j[t]
    with s_j = sum_t p_i[idx_j,t] and c_j = log(residual_j / (V - k)).
    Returns (B,) = 1/J * sum_j KL_j — the per-client form the
    heterogeneous engine descends (clients with different pytrees cannot
    be stacked, so each computes Eq. 2 against the sparse sets that
    actually crossed the client boundary).

    ``impl`` routes kernel impls through ``ops.sparse_mutual_kl`` with
    Kl = 1 and uniform 1/J weights — the fused gather+KL kernel.
    """
    J, B, k = idx.shape
    V = live_logits.shape[-1]
    impl = impl or ops.get_impl()
    idx = jax.lax.stop_gradient(idx)
    logp_top = jax.lax.stop_gradient(logp_top.astype(jnp.float32))
    if impl != "ref":
        pair_w = jnp.full((1, J), 1.0 / max(J, 1), jnp.float32)
        terms = ops.sparse_mutual_kl(live_logits[None], idx, logp_top,
                                     pair_w, temperature=temperature,
                                     impl=impl)
        return terms[0]
    lp_live = jax.nn.log_softmax(
        live_logits.astype(jnp.float32) / temperature, axis=-1)
    p_live = jnp.exp(lp_live)                            # (B,V)
    neg_h = jnp.sum(p_live * lp_live, axis=-1)           # (B,)
    residual = jnp.clip(1.0 - jnp.sum(jnp.exp(logp_top), axis=-1),
                        1e-9, 1.0)                       # (J,B)
    c = jnp.log(residual / max(V - k, 1))                # (J,B)
    p_at = jax.vmap(
        lambda ij: jnp.take_along_axis(p_live, ij, axis=-1))(idx)  # (J,B,k)
    s = jnp.sum(p_at, axis=-1)                           # (J,B)
    cross_top = jnp.sum(p_at * logp_top, axis=-1)        # (J,B)
    kl = neg_h[None] - c * (1.0 - s) - cross_top         # (J,B)
    return jnp.sum(kl, axis=0) / max(J, 1)


def sparse_share_bytes(n_clients: int, n_examples: int, k: int) -> int:
    """Per-round traffic of top-k sharing (int32 idx + fp32 logp, up+down)."""
    return 2 * n_clients * n_examples * k * 8


# ---------------------------------------------------------------------------
# Byzantine-robust Eq.-2 combiners — beyond-paper robustness leg.
# Plain DML averages the KL to every received prediction, so one
# confident-wrong (poisoned) payload pulls every honest client; the robust
# variants replace the mean with a coordinate-wise trimmed mean or median
# CONSENSUS TARGET over the received predictions and descend
# KL(P_i || target_i) instead.  Under no attack and t=0 the trimmed target
# is the plain mean of predictions (close to, but not identical with, the
# mean of KLs — KL is convex), so these are distinct Strategy variants
# ("trimmed-dml" / "median-dml"), not drop-in reparameterisations of DML.

_ABSENT = 1e9          # sort-key shift that pushes masked-out senders last


def robust_weighted_target(shared, recv_mask, mode: str, trim: int = 1):
    """Per-receiver robust consensus over received predictions.

    shared     (K, B) values shared by every client (Bernoulli probs, or
               any per-position scalar payload)
    recv_mask  (K_recv, K) 0/1 — row i selects the senders receiver i
               aggregates over (participants minus self)
    mode       'trimmed' (drop the ``trim`` largest and smallest values
               per position) or 'median'
    Returns (K_recv, B) targets.

    Trace-safe in the participant count: the number of live senders n_i
    is a traced scalar per row.  When n_i - 2*trim < 1 the trimmed mean
    FALLS BACK DETERMINISTICALLY to the untrimmed masked mean (trim
    effectively 0) — the degenerate-participation contract the tests pin.
    """
    if mode not in ("trimmed", "median"):
        raise ValueError(f"robust mode must be 'trimmed' or 'median', "
                         f"got {mode!r}")
    m = jnp.asarray(recv_mask, jnp.float32)            # (Kr, K)
    vals = shared[None, :, :] + (1.0 - m)[:, :, None] * _ABSENT
    s = jnp.sort(vals, axis=1)                         # (Kr, K, B) ascending
    K = shared.shape[0]
    n = jnp.sum(m, axis=1)[:, None, None]              # (Kr, 1, 1) live count
    ranks = jnp.arange(K, dtype=jnp.float32)[None, :, None]
    if mode == "median":
        lo = jnp.floor((n - 1.0) / 2.0)
        hi = jnp.floor(n / 2.0)
        w = 0.5 * ((ranks == lo).astype(jnp.float32) +
                   (ranks == hi).astype(jnp.float32))
        return jnp.sum(s * w, axis=1)
    t = jnp.asarray(float(trim), jnp.float32)
    t_eff = jnp.where(n - 2.0 * t >= 1.0, t, 0.0)      # deterministic fallback
    w = ((ranks >= t_eff) & (ranks < n - t_eff)).astype(jnp.float32)
    return jnp.sum(s * w, axis=1) / jnp.maximum(jnp.sum(w, axis=1), 1.0)


def robust_bernoulli_target(shared, part_mask, mode: str, trim: int = 1):
    """(K, B) shared Bernoulli probs -> (K, B) per-client robust targets
    (each client aggregates over the OTHER participants, as in Eq. 2)."""
    K = shared.shape[0]
    eye = jnp.eye(K, dtype=jnp.float32)
    pm = jnp.ones((K,), jnp.float32) if part_mask is None \
        else jnp.asarray(part_mask, jnp.float32)
    recv = pm[None, :] * (1.0 - eye)
    tgt = robust_weighted_target(shared, recv, mode, trim)
    return jnp.clip(tgt, 1e-6, 1.0 - 1e-6)


def bernoulli_kl_to_target(live_probs, target_probs):
    """Elementwise Bernoulli KL(live || target): (K, B) x (K, B) -> (K, B).
    The robust strategies descend this with the target held fixed."""
    pi = jnp.clip(live_probs.astype(jnp.float32), 1e-6, 1 - 1e-6)
    pj = jnp.clip(jax.lax.stop_gradient(
        target_probs.astype(jnp.float32)), 1e-6, 1 - 1e-6)
    return pi * jnp.log(pi / pj) + (1 - pi) * jnp.log((1 - pi) / (1 - pj))


def robust_categorical_target(received_logits, mode: str, trim: int = 1):
    """(J, B, V) received logits -> (B, V) robust consensus distribution.

    Static J (the hetero engine's per-client view): coordinate-wise
    trimmed mean or median over the J received softmax distributions,
    renormalised back onto the simplex.  J - 2*trim < 1 falls back to the
    untrimmed mean deterministically.
    """
    if mode not in ("trimmed", "median"):
        raise ValueError(f"robust mode must be 'trimmed' or 'median', "
                         f"got {mode!r}")
    probs = jax.nn.softmax(
        received_logits.astype(jnp.float32), axis=-1)   # (J,B,V)
    J = probs.shape[0]
    if mode == "median":
        tgt = jnp.median(probs, axis=0)
    else:
        t = trim if J - 2 * trim >= 1 else 0
        s = jnp.sort(probs, axis=0)
        tgt = jnp.mean(s[t:J - t or None], axis=0)
    tgt = jnp.clip(tgt, 1e-9, 1.0)
    return tgt / jnp.sum(tgt, axis=-1, keepdims=True)


def kl_to_robust_received(live_logits, received_logits, mode: str,
                          trim: int = 1, temperature: float = 1.0):
    """Robust Eq. 2 for ONE client: KL(P_live || robust-consensus of the
    received predictions).  live (B, V) x received (J, B, V) -> (B,).
    The consensus target is data (stop_gradient), like ``kl_to_received``.
    """
    rec = jax.lax.stop_gradient(
        received_logits.astype(jnp.float32) / temperature)
    tgt = jax.lax.stop_gradient(robust_categorical_target(rec, mode, trim))
    lp_live = jax.nn.log_softmax(
        live_logits.astype(jnp.float32) / temperature, axis=-1)
    p_live = jnp.exp(lp_live)
    return jnp.sum(p_live * (lp_live - jnp.log(tgt)), axis=-1)


# ---------------------------------------------------------------------------
# Bernoulli case (VisionNet sigmoid head — the paper's actual case study)

def bernoulli_mutual_terms_vs(live_probs, fixed_probs, pair_w):
    """Rectangular Bernoulli Eq. 2: (Kl, B) live x (Kg, B) fixed -> (Kl, B)
    with explicit (Kl, Kg) pair weights — the device-local shard of
    ``bernoulli_mutual_terms`` (rows = local clients, columns = the
    all-gathered fleet's shared predictions)."""
    pi = jnp.clip(live_probs.astype(jnp.float32), 1e-6, 1 - 1e-6)[:, None, :]
    pj = jnp.clip(fixed_probs.astype(jnp.float32), 1e-6, 1 - 1e-6)[None, :, :]
    kl = pi * jnp.log(pi / pj) + (1 - pi) * jnp.log((1 - pi) / (1 - pj))
    return jnp.sum(kl * pair_w[:, :, None], axis=1)         # (Kl,B)


def bernoulli_mutual_terms(live_probs, fixed_probs, part_mask=None):
    """Eq. 2 with the j-side fixed, Bernoulli case: (K,B) x (K,B) -> (K,B).

    out[i, b] = 1/(K-1) sum_{j != i} KL(Bern(live_i) || Bern(fixed_j)).
    Callers wanting the federated gradient semantics stop_gradient the
    fixed side (received predictions are data, not parameters).
    ``part_mask`` (K,) 0/1 drops non-participants from both sides of the
    average (partial participation: M <= K clients per round).
    """
    K = live_probs.shape[0]
    return bernoulli_mutual_terms_vs(live_probs, fixed_probs,
                                     _pair_mask(K, part_mask))


def bernoulli_mutual_loss(all_probs, stop_grad_others: bool = True,
                          fixed_probs=None, part_mask=None):
    """all_probs: (K, B) sigmoid outputs -> (K,) per-client Eq.-2 means.

    ``fixed_probs`` optionally supplies the received (j-side) predictions —
    e.g. dropout-free shared probabilities while ``all_probs`` is the live
    training-mode forward.  Defaults to ``all_probs`` itself.
    """
    fixed = all_probs if fixed_probs is None else fixed_probs
    if stop_grad_others:
        fixed = jax.lax.stop_gradient(fixed)
    return jnp.mean(bernoulli_mutual_terms(all_probs, fixed,
                                           part_mask=part_mask), axis=-1)


def bernoulli_mutual_eval(all_probs):
    return ref.bernoulli_mutual_kl(all_probs)
