"""Client-stacked pytree helpers shared by the two federated engines.

Both the VisionNet Algorithm-1 engine (``core.federated``) and the
mesh-scale LLM path (``core.distributed``) keep clients as a leading K
axis on every param/opt leaf — the layout the mesh shards over pods and
the round engine vmaps over.  The construction/slicing helpers live here
so the engines cannot drift apart.
"""
from __future__ import annotations

from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

Params = Any

# the mesh axis name the client dimension shards over (launch.mesh builds
# 1-D ("clients",) meshes; multi-pod rules map logical "client" -> "pod")
CLIENT_AXIS = "clients"

# canonical vmap width of the stacked round programs (see
# ``chunked_client_map``): XLA specialises op lowerings on the vmapped
# width (grouped-conv algorithm choice, GEMM/reduce tiling), so programs
# holding different client counts round differently.  Fixing the width
# makes every per-client op's lowering identical whether a program holds
# the full K (unsharded engine) or one device's slice (sharded engine) —
# the foundation of the bitwise sharded == unsharded guarantee.
CLIENT_CHUNK = 2


def stacked_init(key, init_fn: Callable[[jax.Array], Params],
                 n_clients: int) -> Params:
    """K independent initialisations, stacked on a leading client axis."""
    keys = jax.random.split(key, n_clients)
    return jax.vmap(init_fn)(keys)


def broadcast_stack(params: Params, n_clients: int) -> Params:
    """One pytree replicated to a K-stacked pytree (clients start from G)."""
    return jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (n_clients,) + p.shape).copy(),
        params)


def zeros_like_stack(stacked_params: Params) -> Params:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                        stacked_params)


def stacked_sgd_init(stacked_params: Params) -> dict:
    """SGD-momentum state with per-client step counters."""
    k = jax.tree.leaves(stacked_params)[0].shape[0]
    return {"vel": zeros_like_stack(stacked_params),
            "step": jnp.zeros((k,), jnp.int32)}


def expand_stack(tree: Params) -> Params:
    """One pytree -> a K=1 stacked pytree (run a single model through the
    stacked programs; invert with ``client_slice(..., 0)``)."""
    return jax.tree.map(lambda p: p[None], tree)


def client_slice(stacked: Params, c: int) -> Params:
    """Client c's view of a stacked pytree."""
    return jax.tree.map(lambda p: p[c], stacked)


def client_lerp(old_stacked: Params, new_stacked: Params, mask) -> Params:
    """Per-client select on stacked pytrees: client c takes ``new`` where
    mask[c] == 1, keeps ``old`` where 0 (partial-participation broadcast)."""
    m = jnp.asarray(mask, jnp.float32)

    def sel(a, b):
        w = m.reshape((-1,) + (1,) * (a.ndim - 1))
        return (a.astype(jnp.float32) * (1 - w)
                + b.astype(jnp.float32) * w).astype(a.dtype)

    return jax.tree.map(sel, old_stacked, new_stacked)


def stack_params(params_list: Sequence[Params]) -> Params:
    """List of per-client pytrees -> stacked pytree (K on axis 0)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *params_list)


def unstack_params(stacked: Params, k: int):
    return [client_slice(stacked, i) for i in range(k)]


# ---------------------------------------------------------------------------
# device-sharded client axis: round-robin layout + partition-spec/gather
# helpers shared by the shard_map'ed round engines.
#
# Clients spill round-robin over the mesh: global client c lives on device
# c % n_devices at local slot c // n_devices, so an uneven K loads every
# device within one client of its neighbours.  Every device always owns
# K_loc >= 2 slots (short devices wrap around to re-host a real client as a
# masked dummy): XLA specialises size-1 vmapped dims onto different kernels
# (plain vs grouped conv, degenerate batched GEMMs), which breaks the
# bitwise sharded == unsharded parity the engine guarantees.


def client_layout(n_clients: int, n_devices: int):
    """(K_loc, K_pad) for K clients over an n_devices 'clients' mesh axis.
    K_loc is rounded up to a multiple of ``CLIENT_CHUNK`` so every device
    runs whole canonical-width chunks."""
    k_loc = -(-n_clients // n_devices)
    k_loc = -(-k_loc // CLIENT_CHUNK) * CLIENT_CHUNK
    return k_loc, n_devices * k_loc


def chunked_client_map(fn, args, n_clients: int, const_args=(),
                       width: int = CLIENT_CHUNK):
    """Run a stacked-client program in fixed width-``CLIENT_CHUNK`` chunks.

    ``fn`` takes (chunk_args, const_args): ``chunk_args`` mirror ``args``
    (full n_clients-stacked operands) sliced to leading axis ``width``;
    ``const_args`` are passed whole to every chunk (e.g. the shared
    public-fold predictions).  K is padded up to a chunk multiple by
    wrapping (duplicated clients — callers mask/discard the tail) and the
    chunks run under ``lax.map``, so the per-client XLA lowering is
    width-canonical: a device-sharded program holding 2 clients and the
    unsharded program holding all K execute bit-identical per-client
    arithmetic.  optimization_barrier pins every chunk body (inputs,
    constants, outputs) as its own compilation unit — XLA inlines
    trip-count-1 loops, and an inlined body would otherwise fuse with
    surrounding ops and round differently from the same body inside a
    multi-chunk loop.  Returns outputs with leading axis n_clients.
    """
    k_pad = -(-n_clients // width) * width
    if k_pad != n_clients:
        wrap = jnp.arange(k_pad) % n_clients
        args = jax.tree.map(lambda x: jnp.take(x, wrap, axis=0), args)
    n_chunks = k_pad // width
    const_args = jax.lax.optimization_barrier(const_args) if const_args \
        else const_args

    def isolated(chunk_args):
        out = fn(jax.lax.optimization_barrier(chunk_args), const_args)
        return jax.lax.optimization_barrier(out)

    xs = jax.tree.map(lambda x: x.reshape((n_chunks, width) + x.shape[1:]),
                      args)
    out = jax.lax.map(isolated, xs)
    return jax.tree.map(
        lambda x: x.reshape((k_pad,) + x.shape[2:])[:n_clients], out)


def rr_send_indices(n_clients: int, n_devices: int) -> np.ndarray:
    """(K_pad,) gather plan: sharded position p = d * K_loc + i holds global
    client (i * n_devices + d) % K — dummies wrap to real clients so padded
    forwards stay finite (their updates are masked/discarded)."""
    k_loc, k_pad = client_layout(n_clients, n_devices)
    pos = np.arange(k_pad)
    d, i = pos // k_loc, pos % k_loc
    return (i * n_devices + d) % n_clients


def rr_inverse_indices(n_clients: int, n_devices: int) -> np.ndarray:
    """(K_pad,) inverse plan: natural client/pad id c -> sharded position
    (c % n_devices) * K_loc + c // n_devices.  First K entries undo
    ``rr_send_indices``; the tail locates the dummy slots."""
    k_loc, k_pad = client_layout(n_clients, n_devices)
    c = np.arange(k_pad)
    return (c % n_devices) * k_loc + c // n_devices


def shard_clients(tree: Params, n_clients: int, n_devices: int,
                  axis: int = 0) -> Params:
    """Natural K-stacked pytree -> K_pad-stacked round-robin layout."""
    send = jnp.asarray(rr_send_indices(n_clients, n_devices))
    return jax.tree.map(lambda x: jnp.take(x, send, axis=axis), tree)


def unshard_clients(tree: Params, n_clients: int, n_devices: int,
                    axis: int = 0) -> Params:
    """Round-robin K_pad layout -> natural K-stacked pytree (drops dummies)."""
    inv = jnp.asarray(rr_inverse_indices(n_clients, n_devices)[:n_clients])
    return jax.tree.map(lambda x: jnp.take(x, inv, axis=axis), tree)


def client_spec(*tail, axis_name: str = CLIENT_AXIS) -> P:
    """PartitionSpec sharding dim 0 over the client mesh axis; ``tail``
    entries (None or axis names) spec the remaining dims."""
    return P(axis_name, *tail)


def gather_clients(x: jax.Array, n_clients: int, n_devices: int,
                   axis_name: str = CLIENT_AXIS) -> jax.Array:
    """All-gather a per-device (K_loc, ...) shard into the full (K_pad, ...)
    tensor in NATURAL client order (pads trailing) — inside a shard_map
    body this is the round engines' ONLY cross-device collective (the
    public-set predictions of paper Eq. 2)."""
    gathered = jax.lax.all_gather(x, axis_name, axis=0, tiled=True)
    inv = jnp.asarray(rr_inverse_indices(n_clients, n_devices))
    return jnp.take(gathered, inv, axis=0)


def local_client_ids(n_clients: int, n_devices: int,
                     axis_name: str = CLIENT_AXIS) -> jax.Array:
    """(K_loc,) global ids of this device's slots (ids >= n_clients are
    wrapped dummies).  Only meaningful inside a shard_map body."""
    k_loc, _ = client_layout(n_clients, n_devices)
    return jnp.arange(k_loc) * n_devices + jax.lax.axis_index(axis_name)
