"""Client-stacked pytree helpers shared by the two federated engines.

Both the VisionNet Algorithm-1 engine (``core.federated``) and the
mesh-scale LLM path (``core.distributed``) keep clients as a leading K
axis on every param/opt leaf — the layout the mesh shards over pods and
the round engine vmaps over.  The construction/slicing helpers live here
so the engines cannot drift apart.
"""
from __future__ import annotations

from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

Params = Any


def stacked_init(key, init_fn: Callable[[jax.Array], Params],
                 n_clients: int) -> Params:
    """K independent initialisations, stacked on a leading client axis."""
    keys = jax.random.split(key, n_clients)
    return jax.vmap(init_fn)(keys)


def broadcast_stack(params: Params, n_clients: int) -> Params:
    """One pytree replicated to a K-stacked pytree (clients start from G)."""
    return jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (n_clients,) + p.shape).copy(),
        params)


def zeros_like_stack(stacked_params: Params) -> Params:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                        stacked_params)


def stacked_sgd_init(stacked_params: Params) -> dict:
    """SGD-momentum state with per-client step counters."""
    k = jax.tree.leaves(stacked_params)[0].shape[0]
    return {"vel": zeros_like_stack(stacked_params),
            "step": jnp.zeros((k,), jnp.int32)}


def expand_stack(tree: Params) -> Params:
    """One pytree -> a K=1 stacked pytree (run a single model through the
    stacked programs; invert with ``client_slice(..., 0)``)."""
    return jax.tree.map(lambda p: p[None], tree)


def client_slice(stacked: Params, c: int) -> Params:
    """Client c's view of a stacked pytree."""
    return jax.tree.map(lambda p: p[c], stacked)


def client_lerp(old_stacked: Params, new_stacked: Params, mask) -> Params:
    """Per-client select on stacked pytrees: client c takes ``new`` where
    mask[c] == 1, keeps ``old`` where 0 (partial-participation broadcast)."""
    m = jnp.asarray(mask, jnp.float32)

    def sel(a, b):
        w = m.reshape((-1,) + (1,) * (a.ndim - 1))
        return (a.astype(jnp.float32) * (1 - w)
                + b.astype(jnp.float32) * w).astype(a.dtype)

    return jax.tree.map(sel, old_stacked, new_stacked)


def stack_params(params_list: Sequence[Params]) -> Params:
    """List of per-client pytrees -> stacked pytree (K on axis 0)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *params_list)


def unstack_params(stacked: Params, k: int):
    return [client_slice(stacked, i) for i in range(k)]
