"""Roofline report from dry-run JSONL records.

Per (arch x shape x mesh): the three terms
    t_compute    = HLO_FLOPs_per_device / peak_FLOP/s
    t_memory     = HLO_bytes_per_device / HBM_bw
    t_collective = collective_bytes_per_device / link_bw
plus the dominant term, MODEL_FLOPS = 6*N_active*D, the useful-FLOP ratio,
and a rule-based one-liner on what would move the dominant term.

The hardware constants live in ONE place — ``launch.mesh.V5E``
(197 TF bf16 / 819 GB/s HBM / ~50 GB/s ICI); ``roofline_terms`` below is
the single implementation of the three-term model, shared by the dry-run
analyzer (``launch.dryrun``) and the benchmark harness (``benchmarks.run``).

  PYTHONPATH=src python -m repro.analysis.roofline experiments/dryrun/*.jsonl
"""
from __future__ import annotations

import glob
import json
import sys
from typing import Dict, List, Optional

from repro.launch.mesh import V5E, HardwareSpec


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float = 0.0,
                   hw: Optional[HardwareSpec] = None) -> Dict[str, object]:
    """The three-term roofline model for one program / one device.

    Returns ``t_compute`` / ``t_memory`` / ``t_collective`` (seconds at the
    hardware's peaks), ``t_bound`` (their max — the model's minimum
    wall-clock), ``dominant`` (bottleneck attribution: which term binds)
    and ``roofline_frac`` (t_compute / t_bound — 1.0 means the program sits
    on the compute roofline; below 1.0, the gap is memory/collective time).
    """
    hw = hw or V5E
    t = {"t_compute": flops / hw.peak_flops_bf16,
         "t_memory": hbm_bytes / hw.hbm_bandwidth,
         "t_collective": coll_bytes / hw.ici_bandwidth}
    bound = max(t.values())
    t["t_bound"] = bound
    t["dominant"] = max(("t_compute", "t_memory", "t_collective"),
                        key=lambda k: t[k])
    t["roofline_frac"] = t["t_compute"] / bound if bound > 0 else 1.0
    return t


def load(paths: List[str]) -> List[Dict]:
    recs = []
    for pattern in paths:
        for path in sorted(glob.glob(pattern)):
            with open(path) as f:
                for line in f:
                    if line.strip():
                        recs.append(json.loads(line))
    # last record wins per key (re-runs overwrite)
    dedup = {}
    for r in recs:
        dedup[(r["arch"], r["shape"], r["mesh"], r["method"],
               r.get("variant", "baseline"))] = r
    return list(dedup.values())


def _advice(r: Dict) -> str:
    dom = r.get("dominant", "-")
    shape, arch = r["shape"], r["arch"]
    if r["status"] != "ok":
        return "fix the failure first"
    if dom == "t_compute":
        if r.get("useful_flop_ratio", 0) < 0.5:
            return ("compute-bound but <50% useful FLOPs: reduce remat "
                    "recompute / MoE dispatch overhead")
        return "near compute roofline: only larger batch or fewer FLOPs help"
    if dom == "t_memory":
        if shape in ("decode_32k", "long_500k"):
            return ("decode is cache-bandwidth-bound: shrink KV (window/"
                    "quantize) or raise batch to amortise weight reads")
        if shape == "prefill_32k":
            return ("O(S^2) attention buffers dominate: use the flash "
                    "(online-softmax) attention path")
        return ("activation traffic dominates: fuse (flash attention, "
                "chunked CE) and relax remat where VMEM allows")
    if dom == "t_collective":
        return ("collective-bound: check for redundant all-gathers "
                "(FSDP prefetch), move logits sharding, or use top-k "
                "prediction sharing in DML mode")
    return "-"


def table(recs: List[Dict], mesh: str = "single",
          method: str = "standard") -> str:
    rows = [r for r in recs if r["mesh"] == mesh and r["method"] == method
            and r.get("variant", "baseline") == "baseline"]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = ["| arch | shape | t_comp(s) | t_mem(s) | t_coll(s) | dominant | "
           "model TFLOPs | useful | peak GB/dev | advice |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | | | | "
                       f"{r.get('error', '')[:60]} |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.4f} | "
            f"{r['t_memory']:.4f} | {r['t_collective']:.4f} | "
            f"{r['dominant'].replace('t_', '')} | "
            f"{r['model_flops'] / 1e12:.1f} | "
            f"{r['useful_flop_ratio']:.2f} | "
            f"{r['peak_bytes'] / 2**30:.1f} | {_advice(r)} |")
    return "\n".join(out)


def pick_hillclimb(recs: List[Dict]) -> Dict[str, Dict]:
    """The three §Perf pairs: worst roofline fraction, most collective-bound,
    most representative of the paper's technique (the DML case)."""
    ok = [r for r in recs if r["status"] == "ok" and r["mesh"] == "single"
          and r["method"] == "standard"]
    out = {}
    if ok:
        # worst fraction: dominant term vs the best achievable (compute term)
        def waste(r):
            t = max(r["t_compute"], r["t_memory"], r["t_collective"])
            return t / max(r["t_compute"], 1e-12)
        out["worst_fraction"] = max(ok, key=waste)
        out["most_collective"] = max(ok, key=lambda r: r["t_collective"] /
                                     max(r["t_compute"], 1e-12))
    dml = [r for r in recs if r["status"] == "ok" and r["method"] == "dml"]
    if dml:
        out["paper_technique"] = max(dml, key=lambda r: r["t_collective"])
    return out


def main(argv=None) -> int:
    paths = (argv or sys.argv[1:]) or ["experiments/dryrun/*.jsonl"]
    recs = load(paths)
    if not recs:
        print("no records found", file=sys.stderr)
        return 1
    for mesh in ("single", "multi"):
        subset = [r for r in recs if r["mesh"] == mesh
                  and r["method"] == "standard"]
        if subset:
            print(f"\n## Roofline — {mesh}-pod mesh, standard steps "
                  f"({len(subset)} cases)\n")
            print(table(recs, mesh=mesh))
    fl = [r for r in recs if r["method"] in ("dml", "mutual", "fedavg_sync")]
    if fl:
        print("\n## FL methods (multi-pod, clients = pods)\n")
        print("| arch | shape | method | t_coll(s) | pod-axis bytes/dev | "
              "total coll bytes/dev |")
        print("|---|---|---|---|---|---|")
        for r in sorted(fl, key=lambda r: (r["arch"], r["method"])):
            if r["status"] != "ok":
                print(f"| {r['arch']} | {r['shape']} | {r['method']} | FAIL "
                      f"| | {r.get('error', '')[:60]} |")
                continue
            c = r["collectives"]
            print(f"| {r['arch']} | {r['shape']} | {r['method']} | "
                  f"{r['t_collective']:.4f} | {c.get('pod_axis', 0) / 2**20:.1f} MiB | "
                  f"{c['total'] / 2**30:.2f} GiB |")
    picks = pick_hillclimb(recs)
    if picks:
        print("\n## Hillclimb picks\n")
        for why, r in picks.items():
            print(f"- {why}: {r['arch']} x {r['shape']} x {r['method']} "
                  f"(dominant {r.get('dominant', '-')})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
