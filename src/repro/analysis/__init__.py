"""Post-dry-run analysis: roofline terms, bottleneck attribution."""
from repro.analysis import roofline  # noqa: F401
