"""Attack probes — the empirical side of the privacy battery.

The accountant (``privacy.accountant``) upper-bounds what DP-DML can
leak; these probes measure what the protocols DO leak, so the test suite
can pin the ordering the paper's bandwidth argument implies:

    MIA advantage:  DP-DML  <=  DML payloads  <  FedAvg weight deltas

* **Membership inference** (``mia_advantage`` + the two probes): the
  adversary scores examples and thresholds "member / not member".  Under
  FedAvg the adversary holds the client's uploaded weights and scores
  each example by its loss under them (``weight_upload_mia`` — local
  epochs overfit the private fold, so members sit at lower loss).  Under
  DML the adversary only ever sees the (public-fold, prediction) payload
  stream, so it first distills a surrogate of the client from that
  stream (``distill_surrogate``) and loss-thresholds under the surrogate
  (``payload_mia``).  Advantage is the threshold-free
  max_t (TPR - FPR) — the Kolmogorov-Smirnov distance between the member
  and non-member score samples; 0 = chance, 1 = perfect.

* **Gradient inversion / representation leakage**: a parameter-space
  gradient (what FedAvg-style uploads reveal, delta = -lr * sum of
  gradients) leaks the private example's penultimate representation IN
  CLOSED FORM — the sigmoid head gives grad_W_head = h * (p - y) and
  grad_b_head = (p - y), so ``features_from_grad`` recovers h exactly by
  one division.  ``gradient_inversion`` is the standard optimisation
  attack on top (probe image fitted to the observed gradient by cosine
  distance, Adam); ``payload_reconstruction`` is the matched baseline
  for prediction sharing — the best a payload adversary can do is match
  a few output probabilities, which constrains neither the pixels nor
  the representation.

Everything here is observation-side only: probes consume the payload tap
(``population.payload_log``), fold indices (``population.fold_log``) and
parameter pytrees, never the population's internals.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.models.visionnet import (_CONV_IMPLS, _max_pool, bce_loss,
                                    init_visionnet, visionnet_forward)

# ---------------------------------------------------------------------------
# scoring


def mia_advantage(member_scores, non_member_scores) -> float:
    """max_t (TPR - FPR) of the rule "score >= t -> member".

    Threshold-free: sweeps every achievable threshold (the KS statistic
    of the two score samples).  Scores must be oriented so members are
    expected HIGHER (e.g. pass negated losses).  Returns a float in
    [0, 1]; chance = 0 even when the two samples differ in size.
    """
    m = np.sort(np.asarray(member_scores, np.float64))
    n = np.sort(np.asarray(non_member_scores, np.float64))
    if len(m) == 0 or len(n) == 0:
        raise ValueError("need at least one member and one non-member score")
    thr = np.concatenate([m, n])
    tpr = 1.0 - np.searchsorted(m, thr, side="left") / len(m)
    fpr = 1.0 - np.searchsorted(n, thr, side="left") / len(n)
    return float(np.max(tpr - fpr))


def per_example_bce(probs, labels, eps: float = 1e-7) -> np.ndarray:
    """Elementwise Bernoulli cross-entropy (``models.visionnet.bce_loss``
    is the batch MEAN; the attacks need the per-example vector)."""
    p = np.clip(np.asarray(probs, np.float64), eps, 1.0 - eps)
    y = np.asarray(labels, np.float64)
    return -(y * np.log(p) + (1.0 - y) * np.log(1.0 - p))


# ---------------------------------------------------------------------------
# membership inference


def weight_upload_mia(params, vn_cfg, images, labels, member_idx,
                      non_member_idx, batch: int = 256) -> float:
    """MIA against a WEIGHT upload: the adversary runs the uploaded
    client model and loss-thresholds.  ``params`` is one client's
    (unstacked) pytree; returns the advantage."""
    losses = model_example_losses(params, vn_cfg, images, labels, batch)
    return mia_advantage(-losses[np.asarray(member_idx)],
                         -losses[np.asarray(non_member_idx)])


def model_example_losses(params, vn_cfg, images, labels,
                         batch: int = 256) -> np.ndarray:
    """Per-example BCE of a VisionNet under ``params`` over a pool."""
    out = []
    for i in range(0, len(images), batch):
        probs = visionnet_forward(params, vn_cfg,
                                  jnp.asarray(images[i:i + batch]),
                                  train=False)
        out.append(per_example_bce(np.asarray(probs), labels[i:i + batch]))
    return np.concatenate(out)


def _adam_scan(obj, x0, steps: int, lr: float):
    """Minimise ``obj`` over an array with inlined Adam — the attack
    optimiser (SGD stalls on the ill-conditioned inversion objectives)."""

    def step(carry, i):
        x, m, v = carry
        g = jax.grad(obj)(x)
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        mh = m / (1 - 0.9 ** (i + 1.0))
        vh = v / (1 - 0.999 ** (i + 1.0))
        x = x - lr * mh / (jnp.sqrt(vh) + 1e-8)
        return (x, m, v), ()

    (x, _, _), _ = jax.lax.scan(
        step, (x0, jnp.zeros_like(x0), jnp.zeros_like(x0)),
        jnp.arange(steps, dtype=jnp.float32))
    return x


def distill_surrogate(vn_cfg, pub_images, target_probs, key,
                      steps: int = 200, lr: float = 0.05):
    """Train a surrogate VisionNet to mimic an observed payload stream.

    ``pub_images`` (N, H, W, C) public examples and ``target_probs`` (N,)
    the probabilities the victim shared on them — the ONLY things a
    DML-payload adversary holds.  Full-batch BCE-to-soft-targets descent;
    returns the surrogate params.
    """
    params = init_visionnet(key, vn_cfg)
    imgs = jnp.asarray(pub_images)
    tgt = jnp.asarray(target_probs, jnp.float32)

    @jax.jit
    def run(params):
        def soft_bce(p):
            pr = jnp.clip(visionnet_forward(p, vn_cfg, imgs, train=False),
                          1e-7, 1 - 1e-7)
            return -jnp.mean(tgt * jnp.log(pr) +
                             (1 - tgt) * jnp.log(1 - pr))

        def step(carry, _):
            p, vel = carry
            g = jax.grad(soft_bce)(p)
            vel = jax.tree.map(lambda v, gg: 0.9 * v + gg, vel, g)
            p = jax.tree.map(lambda q, v: q - lr * v, p, vel)
            return (p, vel), ()

        vel = jax.tree.map(jnp.zeros_like, params)
        (params, _), _ = jax.lax.scan(step, (params, vel), None,
                                      length=steps)
        return params

    return run(params)


def payload_mia(vn_cfg, pub_images, target_probs, images, labels,
                member_idx, non_member_idx, key,
                steps: int = 200, lr: float = 0.05) -> float:
    """MIA against a PREDICTION payload stream: distill a surrogate from
    the observed (public image, shared probability) pairs, then
    loss-threshold under the surrogate.  The same probe measures plain
    DML (raw payloads) and DP-DML (noised payloads) — the payload tensors
    are whatever actually crossed the wire."""
    surrogate = distill_surrogate(vn_cfg, pub_images, target_probs, key,
                                  steps=steps, lr=lr)
    return weight_upload_mia(surrogate, vn_cfg, images, labels,
                             member_idx, non_member_idx)


def collect_client_payloads(payload_log, images, client: int):
    """Flatten a ``VisionClients.payload_log`` into the (public images,
    shared probs) pairs an eavesdropper observed from ``client``:
    returns (imgs (N, H, W, C), probs (N,)) over all rounds/epochs."""
    im, pr = [], []
    for rec in payload_log:
        pay = rec["payloads"]                      # (E, K, B)
        pub = rec["public"]
        for e in range(pay.shape[0]):
            im.append(images[pub])
            pr.append(pay[e, client])
    if not im:
        raise ValueError("payload_log is empty — construct the population "
                         "with record_payloads=True and run rounds first")
    return np.concatenate(im), np.concatenate(pr)


# ---------------------------------------------------------------------------
# gradient inversion


def example_gradient(params, vn_cfg, x, y):
    """The parameter-space gradient a weight-sharing round reveals for a
    (batch of) private example(s): grad_theta BCE(f_theta(x), y)."""
    return jax.grad(lambda p: bce_loss(
        visionnet_forward(p, vn_cfg, jnp.asarray(x), train=False),
        jnp.asarray(y)))(params)


def dense_features(params, vn_cfg, images):
    """The penultimate (post-dense, pre-head) representation h: (B, D).
    Mirrors ``visionnet_forward`` dropout-free up to the head."""
    x = jnp.asarray(images).astype(jnp.float32)
    conv = _CONV_IMPLS["native"]
    for i, cp in enumerate(params["conv"]):
        x = jax.nn.relu(conv(x, cp["w"], cp["b"]))
        if i < 2:
            x = _max_pool(x)
    x = x.reshape(x.shape[0], -1)
    return jax.nn.relu(x @ params["dense"]["w"] + params["dense"]["b"])


def features_from_grad(grad) -> np.ndarray:
    """EXACT representation recovery from one example's gradient.

    The sigmoid head is linear in h: grad_W_head = h * (p - y) and
    grad_b_head = (p - y), so h = grad_W_head[:, 0] / grad_b_head[0] —
    a weight upload hands the adversary the private example's penultimate
    representation in closed form, no optimisation needed.  (Undefined
    when p == y exactly; the probe uses examples the model is not yet
    perfect on.)
    """
    gw = np.asarray(grad["head"]["w"])[:, 0]
    gb = float(np.asarray(grad["head"]["b"])[0])
    if abs(gb) < 1e-12:
        raise ValueError("grad_b_head == 0 (p == y exactly); the head "
                         "gradient carries no scale to divide out")
    return gw / gb


def cosine_similarity(a, b) -> float:
    a = np.asarray(a, np.float64).ravel()
    b = np.asarray(b, np.float64).ravel()
    return float(np.dot(a, b) /
                 (np.linalg.norm(a) * np.linalg.norm(b) + 1e-12))


def gradient_inversion(params, vn_cfg, target_grad, x_shape, y, key,
                       steps: int = 800, lr: float = 0.1):
    """The standard inverting-gradients attack: optimise a probe batch x
    (Adam) to minimise the cosine distance between
    grad_theta BCE(f_theta(x), y) and the observed ``target_grad``.
    Returns (reconstruction, final cosine distance).  On VisionNet the
    pooled conv stack makes pixel recovery ill-posed — the closed-form
    ``features_from_grad`` is the assertive probe; this one measures how
    tightly the observed gradient constrains the adversary's search
    (final distance << 1 even when the pixels are not unique)."""
    flat_tgt, _ = ravel_pytree(jax.tree.map(jnp.asarray, target_grad))
    x0 = 0.1 * jax.random.normal(key, x_shape, jnp.float32)
    yy = jnp.asarray(y)

    def cosine_obj(x):
        g = jax.grad(lambda p: bce_loss(
            visionnet_forward(p, vn_cfg, x, train=False), yy))(params)
        fg, _ = ravel_pytree(g)
        denom = jnp.linalg.norm(fg) * jnp.linalg.norm(flat_tgt) + 1e-12
        return 1.0 - jnp.dot(fg, flat_tgt) / denom

    run = jax.jit(lambda x0: _adam_scan(cosine_obj, x0, steps, lr))
    x = run(x0)
    return np.asarray(x), float(cosine_obj(x))


def payload_reconstruction(vn_cfg, surrogate_params, prob, x_shape, key,
                           steps: int = 800, lr: float = 0.1):
    """The matched payload-only baseline: all a prediction payload pins
    down is a few output probabilities, so the best reconstruction
    objective available is "find x whose prediction matches the shared
    prob" — which constrains neither the pixels nor the representation.
    Returns the (chance-level) reconstruction."""
    x0 = 0.1 * jax.random.normal(key, x_shape, jnp.float32)
    p_tgt = jnp.asarray(prob, jnp.float32)

    def obj(x):
        pr = visionnet_forward(surrogate_params, vn_cfg, x, train=False)
        return jnp.mean((pr - p_tgt) ** 2)

    run = jax.jit(lambda x0: _adam_scan(obj, x0, steps, lr))
    return np.asarray(run(x0))


def reconstruction_error(x_rec, x_true) -> float:
    """Scale-invariant per-pixel error: MSE after matching mean/std (an
    inversion that recovers structure up to affine intensity still
    counts; pure noise does not)."""
    a = np.asarray(x_rec, np.float64).ravel()
    b = np.asarray(x_true, np.float64).ravel()
    a = (a - a.mean()) / (a.std() + 1e-12)
    b = (b - b.mean()) / (b.std() + 1e-12)
    # sign-invariant too: cosine objectives can invert contrast
    return float(min(np.mean((a - b) ** 2), np.mean((a + b) ** 2)))
