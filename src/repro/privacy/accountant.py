"""Rényi (moments) accountant for the Gaussian-mechanism releases DP-DML
makes — pure Python/NumPy math, no jax dependency, checkpointable.

Every mutual epoch each participant releases ONE clipped +
Gaussian-noised payload (its public-set predictions), i.e. one Gaussian
mechanism invocation with L2 sensitivity ``clip`` and noise std
``clip * noise_multiplier``.  The Rényi divergence of that mechanism is

    eps_rdp(alpha) = alpha / (2 sigma^2)          (sigma = noise_multiplier)

and RDP composes additively across releases, so the whole federation's
privacy curve is a single coefficient

    S = sum_t 1 / (2 sigma_t^2)      with   eps_rdp(alpha) = alpha * S.

Conversion to (ε, δ) uses the standard RDP-to-DP bound
``eps = eps_rdp(alpha) + log(1/δ)/(alpha-1)`` minimised over alpha > 1,
which for the linear-in-alpha curve above has the closed-form minimiser
``alpha* = 1 + sqrt(log(1/δ)/S)`` giving

    eps(δ) = S + 2 sqrt(S log(1/δ)).

For a SINGLE release (S = 1/(2σ²)) this collapses to the textbook
Gaussian-mechanism RDP bound ``1/(2σ²) + sqrt(2 log(1/δ))/σ`` —
``gaussian_epsilon`` below — which the tests hold the accountant to
within 1e-6 (the oracle is also re-derived numerically over an alpha
grid there).

No subsampling amplification is modelled: every participant releases its
full payload every mutual epoch, so the sampling rate is 1 and plain RDP
composition is tight for this protocol.
"""
from __future__ import annotations

import math
from typing import Dict, List


def gaussian_epsilon(noise_multiplier: float, delta: float) -> float:
    """Closed-form single-release (ε, δ) of the Gaussian mechanism with
    noise std = ``noise_multiplier`` × sensitivity, via the RDP curve
    alpha/(2σ²) optimised analytically over alpha."""
    if noise_multiplier <= 0:
        return math.inf
    if not 0 < delta < 1:
        raise ValueError(f"delta must be in (0, 1), got {delta}")
    s = noise_multiplier
    return 1.0 / (2 * s * s) + math.sqrt(2 * math.log(1 / delta)) / s


class RDPAccountant:
    """Tracks the composed RDP coefficient of a sequence of (full-batch)
    Gaussian releases and converts it to (ε, δ) on demand.

    ``step(noise_multiplier, releases=n)`` records n releases at that
    noise level; ``epsilon(delta)`` returns the tightest ε the linear RDP
    curve yields.  ``state()``/``load_state()`` round-trip everything
    (used by ``DPDML.save_state`` through ``Federation``).
    """

    def __init__(self) -> None:
        self._coeff = 0.0            # S = sum_t 1/(2 sigma_t^2)
        self._releases = 0
        self._log: List[Dict] = []   # [{"sigma": s, "releases": n}, ...]

    # -- recording ---------------------------------------------------------
    def step(self, noise_multiplier: float, releases: int = 1) -> None:
        if noise_multiplier <= 0:
            raise ValueError(
                f"noise_multiplier must be > 0, got {noise_multiplier} "
                "(a noiseless release has no finite privacy curve)")
        if releases <= 0:
            return
        self._coeff += releases / (2.0 * noise_multiplier ** 2)
        self._releases += int(releases)
        # coalesce the (very common) same-sigma streak so the log stays
        # O(#distinct sigmas), not O(#rounds)
        if self._log and self._log[-1]["sigma"] == float(noise_multiplier):
            self._log[-1]["releases"] += int(releases)
        else:
            self._log.append({"sigma": float(noise_multiplier),
                              "releases": int(releases)})

    @property
    def releases(self) -> int:
        return self._releases

    @property
    def rdp_coeff(self) -> float:
        """S such that eps_rdp(alpha) = alpha * S."""
        return self._coeff

    # -- conversion --------------------------------------------------------
    def best_alpha(self, delta: float) -> float:
        """The alpha that minimises the RDP-to-DP conversion."""
        if self._coeff <= 0:
            return math.inf
        return 1.0 + math.sqrt(math.log(1 / delta) / self._coeff)

    def epsilon(self, delta: float) -> float:
        """(ε, δ)-DP guarantee of everything recorded so far."""
        if not 0 < delta < 1:
            raise ValueError(f"delta must be in (0, 1), got {delta}")
        if self._coeff <= 0:
            return 0.0
        s = self._coeff
        return s + 2.0 * math.sqrt(s * math.log(1 / delta))

    # -- checkpoint --------------------------------------------------------
    def state(self) -> Dict:
        return {"coeff": self._coeff, "releases": self._releases,
                "log": [dict(e) for e in self._log]}

    def load_state(self, state: Dict) -> None:
        self._coeff = float(state["coeff"])
        self._releases = int(state["releases"])
        self._log = [dict(e) for e in state.get("log", [])]


def calibrate_noise(target_epsilon: float, delta: float, releases: int,
                    tol: float = 1e-9) -> float:
    """Smallest noise multiplier whose ``releases``-fold composition stays
    within (target_epsilon, delta) — the inverse of the accountant, via
    bisection on sigma (epsilon is strictly decreasing in sigma)."""
    if target_epsilon <= 0:
        raise ValueError(f"target_epsilon must be > 0, got {target_epsilon}")
    if releases <= 0:
        raise ValueError(f"releases must be > 0, got {releases}")

    def eps(sigma: float) -> float:
        s = releases / (2.0 * sigma * sigma)
        return s + 2.0 * math.sqrt(s * math.log(1 / delta))

    lo, hi = 1e-3, 1.0
    while eps(hi) > target_epsilon:
        hi *= 2.0
        if hi > 1e9:
            raise ValueError("cannot calibrate: target epsilon too small")
    while hi - lo > tol * hi:
        mid = 0.5 * (lo + hi)
        if eps(mid) > target_epsilon:
            lo = mid
        else:
            hi = mid
    return hi
