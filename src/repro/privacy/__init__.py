"""Privacy & robustness toolkit — makes the paper's central claim
("sharing public-set predictions preserves data privacy") executable.

Three legs, each with its own module:

  accountant  Rényi/moments (ε, δ) accounting for the Gaussian mechanism
              releases DP-DML makes every mutual epoch, validated against
              the closed-form single-release bound.
  dp          the clip + Gaussian-noise payload transforms applied to
              shared predictions BEFORE they cross client boundaries.
  attacks     the probes that turn the privacy claim into an assertion:
              loss-threshold/shadow membership inference and
              gradient-inversion reconstruction, run against both DML
              prediction payloads and FedAvg weight uploads.

The strategies that consume this package live in
``repro.core.strategies`` (``DPDML``, ``TrimmedDML``, ``MedianDML``);
the verification battery in ``tests/test_privacy_*.py`` and
``benchmarks/run.py --table privacy``.
"""
from repro.privacy.accountant import (RDPAccountant, calibrate_noise,
                                      gaussian_epsilon)
from repro.privacy.attacks import (cosine_similarity, dense_features,
                                   example_gradient, features_from_grad,
                                   gradient_inversion, mia_advantage,
                                   payload_mia, payload_reconstruction,
                                   reconstruction_error, weight_upload_mia)
from repro.privacy.dp import DPSpec, clip_payload, dp_noise_payload

__all__ = [
    "RDPAccountant", "gaussian_epsilon", "calibrate_noise",
    "DPSpec", "clip_payload", "dp_noise_payload",
    "mia_advantage", "weight_upload_mia", "payload_mia",
    "example_gradient", "dense_features", "features_from_grad",
    "cosine_similarity",
    "gradient_inversion", "payload_reconstruction", "reconstruction_error",
]
