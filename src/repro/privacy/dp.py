"""Clip + Gaussian-noise transforms for shared prediction payloads —
what DP-DML applies BEFORE predictions cross client boundaries.

The DP unit is one client's whole per-epoch payload: the (positions,)
Bernoulli probability vector (VisionClients) or the (positions, V) logit
tensor (HeteroClients), flattened and L2-clipped to ``clip`` so the
Gaussian mechanism's sensitivity is bounded by construction, then noised
with std ``clip * noise_multiplier``.  The accountant
(``privacy.accountant``) charges one Gaussian release per client per
mutual epoch for exactly this transform.

All transforms are jit-safe (shape-static, branch-free): a
``noise_multiplier`` of 0 with an infinite ``clip`` is an EXACT no-op
(the gating keeps the payload bitwise-unchanged), which lets one program
serve both the DP and non-DP paths without perturbing parity.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclass
class DPSpec:
    """One round's DP parameters, handed by ``DPDML`` to the population.

    clip              L2 bound on each client's flattened payload
    noise_multiplier  noise std in units of ``clip``
    keys              (mutual_epochs, 2) uint32 PRNG keys, one per epoch
                      (the population folds the client index in, so every
                      client's release draws independent noise)
    """
    clip: float
    noise_multiplier: float
    keys: Any = None


def clip_payload(payload, clip: float):
    """L2-clip each leading-axis slice of ``payload`` (one slice = one
    client's release), flattening the rest: ``x * min(1, clip/||x||)``."""
    flat = payload.reshape(payload.shape[0], -1)
    norm = jnp.linalg.norm(flat, axis=-1, keepdims=True)
    scale = jnp.minimum(1.0, clip / jnp.maximum(norm, 1e-12))
    return (flat * scale).reshape(payload.shape)


def dp_noise_payload(payload, clip: float, noise_multiplier: float, key,
                     center: Optional[float] = None):
    """Clip + Gaussian-noise one stacked payload (K releases at once).

    payload: (K, ...) — leading axis is the releasing client.
    ``center`` (e.g. 0.5 for Bernoulli probabilities) is subtracted before
    clipping and added back after noising, so the clip bound measures the
    informative deviation rather than the constant offset.

    ``noise_multiplier <= 0`` returns the payload bitwise-unchanged (the
    branch is a lax.cond-free where-gate, so the same jitted program
    serves DP and non-DP rounds).
    """
    x = payload if center is None else payload - center
    clipped = clip_payload(x, clip)
    noise = noise_multiplier * clip * jax.random.normal(
        key, payload.shape, jnp.float32)
    noised = clipped + noise.astype(payload.dtype)
    if center is not None:
        noised = noised + center
    apply = (jnp.asarray(noise_multiplier, jnp.float32) > 0)
    return jnp.where(apply, noised, payload)


def dp_probs_payload(probs, clip: float, noise_multiplier: float, key):
    """Bernoulli-probability payloads: center at 0.5, clip+noise, clamp
    back into the open unit interval so downstream KL terms stay finite."""
    out = dp_noise_payload(probs, clip, noise_multiplier, key, center=0.5)
    apply = (jnp.asarray(noise_multiplier, jnp.float32) > 0)
    return jnp.where(apply, jnp.clip(out, 1e-4, 1.0 - 1e-4), probs)
