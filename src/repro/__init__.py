"""Federated Learning via Distributed Mutual Learning — JAX reproduction.

Public surface (PEP-562 lazy so ``import repro`` stays cheap and
cycle-free; everything resolves through :mod:`repro.api`):

    repro.Federation          the strategy-composable session layer
    repro.DML / SparseDML / FedAvg / AsyncWeights     sharing strategies
    repro.DPDML / TrimmedDML / MedianDML    privacy & robustness variants
    repro.VisionClients / HeteroClients / LMClients   client populations
    repro.checkpoint          flat-npz pytree checkpointing

Everything else (kernels, models, launch drivers) is importable as
submodules: ``repro.core``, ``repro.models``, ``repro.kernels``, ...
"""
from __future__ import annotations

__version__ = "0.5.0"

__all__ = [
    "Federation", "History", "RoundLog",
    "Strategy", "Payload", "get_strategy",
    "DML", "SparseDML", "FedAvg", "AsyncWeights",
    "DPDML", "TrimmedDML", "MedianDML",
    "Population", "VisionClients", "HeteroClients", "LMClients",
    "api", "checkpoint", "__version__",
]

_API_NAMES = {
    "Federation", "History", "RoundLog", "Strategy", "Payload",
    "get_strategy", "DML", "SparseDML", "FedAvg", "AsyncWeights",
    "DPDML", "TrimmedDML", "MedianDML",
    "Population", "VisionClients", "HeteroClients", "LMClients",
}


def __getattr__(name: str):
    if name in _API_NAMES:
        from repro import api
        return getattr(api, name)
    if name in ("api", "checkpoint", "core", "sharding"):
        import importlib
        return importlib.import_module(f"repro.{name}")
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
