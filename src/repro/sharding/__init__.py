"""Logical-axis sharding rules.

Models annotate activations/params with *logical* axis names
(``batch``, ``heads``, ``ff``, ``vocab``, ``expert``, ``client`` ...); this
module maps them to physical mesh axes and produces PartitionSpecs.  The map
is swappable (hillclimbing changes it without touching model code).

Physical mesh axes:
  - single-pod: ("data", "model")
  - multi-pod:  ("pod", "data", "model")  -- "pod" doubles as the FL client axis
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# Default logical->physical rules.  ``fsdp`` shards params over the data axis
# (ZeRO-3 style); ``tensor`` is megatron tensor parallel.
DEFAULT_RULES: Dict[str, Optional[str]] = {
    "batch": ("pod", "data"),     # standard mode: pure DP across pods
    "attn_batch": ("pod", "data"),  # attention activations; hillclimb remaps
    # FL client axis: a dedicated 1-D "clients" mesh (launch.mesh
    # .make_client_mesh — the shard_map'ed round engines) when present,
    # else the multi-pod "pod" axis; remapped in tests
    "client": ("clients", "pod"),
    "seq": None,
    "res_seq": None,     # residual-stream seq dim; "seqpar" variant -> model
    "kv_seq": "model",        # decode KV-cache sequence sharding when heads < tp
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "embed": "data",          # FSDP: param d_model dim over data
    "embed_act": None,        # activation d_model dim stays unsharded
    "ff": "model",
    "vocab": "model",
    "expert": "model",
    "expert_ff": None,
    "conv": None,
    "state": None,
    "layers": None,
}

_local = threading.local()


def get_rules() -> Dict[str, Optional[str]]:
    return getattr(_local, "rules", DEFAULT_RULES)


@contextlib.contextmanager
def axis_rules(rules: Dict[str, Optional[str]]):
    """Override logical->physical mapping (e.g. tests map client->data)."""
    old = get_rules()
    _local.rules = {**old, **rules}
    try:
        yield
    finally:
        _local.rules = old


def _mesh_axis_sizes(mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.axis_sizes))


def logical_to_spec(logical: Tuple[Optional[str], ...], mesh=None,
                    shape: Optional[Tuple[int, ...]] = None) -> P:
    """Map logical axis names to a PartitionSpec valid on ``mesh``.

    Axes not in the rules / not on the mesh / not dividing the dim size are
    dropped (replicated).  Duplicate physical axes keep first occurrence.
    """
    rules = get_rules()
    sizes = _mesh_axis_sizes(mesh) if mesh is not None else None
    used = set()
    out = []
    for i, name in enumerate(logical):
        phys = rules.get(name) if name is not None else None
        if phys is None:
            out.append(None)
            continue
        cand = (phys,) if isinstance(phys, str) else tuple(phys)
        # keep axes that exist on the mesh and are not already used
        cand = tuple(a for a in cand
                     if (sizes is None or a in sizes) and a not in used)
        if sizes is not None and shape is not None:
            # greedy prefix whose product divides the dim size
            kept = []
            prod = 1
            for a in cand:
                if shape[i] % (prod * sizes[a]) == 0:
                    kept.append(a)
                    prod *= sizes[a]
            cand = tuple(kept)
        if not cand:
            out.append(None)
            continue
        used.update(cand)
        out.append(cand[0] if len(cand) == 1 else cand)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def current_mesh():
    """The ambient (abstract) mesh, or None when unsharded.

    jax >= 0.5 exposes ``jax.sharding.get_abstract_mesh``; on older releases
    the same state lives in ``jax._src.mesh`` (where the getter may return a
    bare context tuple instead of a mesh) with the physical mesh stack as a
    further fallback.
    """
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        mesh = getter()
    else:
        from jax._src import mesh as _mesh_lib
        mesh = getattr(_mesh_lib, "get_abstract_mesh", lambda: None)()
        if not hasattr(mesh, "axis_names"):
            mesh = _mesh_lib.thread_resources.env.physical_mesh
    if mesh is None or not hasattr(mesh, "axis_names"):
        return None
    if mesh.empty or not mesh.axis_names:
        return None
    return mesh


def shard_map(f, mesh, in_specs, out_specs):
    """Uncheck-replicated shard_map across jax versions (check_vma on >= 0.6,
    check_rep + experimental namespace before)."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def make_mesh(axis_shapes, axis_names, devices=None):
    """jax.make_mesh with Auto axis types where the version supports them."""
    axis_type = getattr(getattr(jax.sharding, "AxisType", None), "Auto", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(axis_shapes, axis_names, devices=devices,
                                 axis_types=(axis_type,) * len(axis_names))
        except TypeError:
            pass
    return jax.make_mesh(axis_shapes, axis_names, devices=devices)


def use_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    jax >= 0.6 spells this ``jax.set_mesh``; before that the Mesh object is
    itself the context manager.
    """
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        return setter(mesh)
    return mesh


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axis names; no-op without a mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = logical_to_spec(tuple(logical), mesh, x.shape)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def named_sharding(mesh, *logical, shape=None) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(tuple(logical), mesh, shape))


def spec_tree_like(logical_tree, mesh, shape_tree):
    """Map a pytree of logical-axis tuples to NamedShardings."""
    return jax.tree.map(
        lambda log, sd: NamedSharding(mesh, logical_to_spec(log, mesh, sd.shape)),
        logical_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )
