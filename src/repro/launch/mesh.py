"""Production meshes (TPU v5e numbers) + hardware constants for roofline.

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state (the dry-run sets the host-device count before
any jax initialisation).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax

from repro.sharding import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """single-pod: (data=16, model=16) = 256 chips;
    multi-pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_cpu_mesh(shape=(2, 2), axes=("data", "model")):
    """Small host-device mesh for tests (requires the XLA host-device flag)."""
    return make_mesh(shape, axes)


@dataclass(frozen=True)
class HardwareSpec:
    """TPU v5e (the dry-run/roofline target)."""
    name: str = "tpu_v5e"
    peak_flops_bf16: float = 197e12       # per chip
    hbm_bandwidth: float = 819e9          # bytes/s per chip
    ici_bandwidth: float = 50e9           # bytes/s per link
    hbm_bytes: int = 16 * 1024 ** 3


V5E = HardwareSpec()
