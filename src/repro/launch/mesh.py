"""Production meshes (TPU v5e numbers) + hardware constants for roofline.

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state (the dry-run sets the host-device count before
any jax initialisation).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax

from repro.sharding import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """single-pod: (data=16, model=16) = 256 chips;
    multi-pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_cpu_mesh(shape=(2, 2), axes=("data", "model")):
    """Small host-device mesh for tests (requires the XLA host-device flag)."""
    return make_mesh(shape, axes)


def make_client_mesh(n_devices: int = 0):
    """1-D ``clients`` mesh over the first n devices (0 -> all available).

    The federated round engines shard whole clients over this axis;
    K > n_devices spills round-robin (core.stacking.client_layout).  On a
    CPU-only host, fake devices come from
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` set before jax
    initialises (tests/conftest.py and benchmarks/run.py do this).
    """
    devs = jax.devices()
    n = n_devices or len(devs)
    if n > len(devs):
        raise ValueError(
            f"mesh wants {n} devices but only {len(devs)} are visible; on "
            "CPU set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n} before jax initialises")
    return make_mesh((n,), ("clients",), devices=devs[:n])


def parse_mesh_spec(spec: str) -> dict:
    """'clients=4' / 'clients=4,data=2' -> {'clients': 4, 'data': 2}."""
    out = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        name, _, num = part.partition("=")
        if not num.isdigit():
            raise ValueError(f"bad mesh spec {spec!r}: expected axis=N")
        out[name.strip()] = int(num)
    return out


@dataclass(frozen=True)
class HardwareSpec:
    """TPU v5e (the dry-run/roofline target)."""
    name: str = "tpu_v5e"
    peak_flops_bf16: float = 197e12       # per chip
    hbm_bandwidth: float = 819e9          # bytes/s per chip
    ici_bandwidth: float = 50e9           # bytes/s per link
    hbm_bytes: int = 16 * 1024 ** 3


V5E = HardwareSpec()
