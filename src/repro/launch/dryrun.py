import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) on 512
placeholder host devices, and dump cost/memory/collective analysis to JSON
for the roofline report.

The two lines above MUST stay the first statements in this module — jax
locks the device count on first init (see the task brief).

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --out experiments/dryrun
  python -m repro.launch.dryrun --arch dbrx-132b --shape train_4k \
      --mesh multi --method dml          # clients = pods
"""
import argparse
import json
import re
import sys
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding as shd
from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.core import distributed as dml
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (decode_window, make_decode_step,
                                make_prefill_step, make_train_step)
from repro.optim import AdamWConfig

DTYPE_BYTES = {
    "pred": 0.125, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8, "c64": 8,
}
COLLECTIVE_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^\n]*")
SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,{} ]*)\}\}")
GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
SOURCE_TARGET_RE = re.compile(r"source_target_pairs=\{([0-9,{} ]*)\}")


def _parse_groups(line: str):
    """Replica groups as a list of id-lists (both HLO formats), or None."""
    m = GROUPS_IOTA_RE.search(line)
    if m:
        import numpy as _np
        g, n = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        arr = _np.arange(int(_np.prod(dims))).reshape(dims)
        if m.group(4):
            arr = arr.transpose([int(d) for d in m.group(4).split(",")])
        return arr.reshape(g, n).tolist()
    m = GROUPS_LIST_RE.search(line)
    if m:
        out = []
        for grp in m.group(1).split("},{"):
            ids = [int(x) for x in
                   grp.replace("{", "").replace("}", "").split(",")
                   if x.strip()]
            if ids:
                out.append(ids)
        return out or None
    m = SOURCE_TARGET_RE.search(line)
    if m:
        ids = [int(x) for x in re.findall(r"\d+", m.group(1))]
        return [list(p) for p in zip(ids[::2], ids[1::2])]
    return None


def _pod_class(line: str, pod_stride: int) -> str:
    """'intra' (groups within one pod), 'pod_axis' (groups vary ONLY in pod
    index — the client-axis traffic), or 'mixed' (spanning both)."""
    groups = _parse_groups(line)
    if not groups:
        return "intra"
    crosses = any(i // pod_stride != g[0] // pod_stride
                  for g in groups for i in g)
    if not crosses:
        return "intra"
    pure = all(len({i % pod_stride for i in g}) == 1 for g in groups)
    return "pod_axis" if pure else "mixed"


def _type_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str, pod_stride: int = 256) -> Dict[str, float]:
    """Per-device bytes by collective kind, parsed from partitioned HLO.
    ``cross_pod`` separates traffic whose replica groups span pods — the
    client-axis (DCN-class) traffic the paper's bandwidth claim is about."""
    out: Dict[str, float] = {"all-gather": 0.0, "all-reduce": 0.0,
                             "reduce-scatter": 0.0, "all-to-all": 0.0,
                             "collective-permute": 0.0, "count": 0,
                             "cross_pod": 0.0, "pod_axis": 0.0}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        b = _type_bytes(type_str)
        out[kind] += b
        out["count"] += 1
        cls = _pod_class(m.group(0), pod_stride)
        if cls != "intra":
            out["cross_pod"] += b
        if cls == "pod_axis":
            out["pod_axis"] += b
    out["total"] = sum(v for k, v in out.items()
                       if k not in ("count", "total", "cross_pod", "pod_axis"))
    return out


def _shardings(tree_specs, tree_axes, mesh):
    def leaf(ax, sd):
        return jax.NamedSharding(
            mesh, shd.logical_to_spec(tuple(ax), mesh, sd.shape))
    return jax.tree.map(
        leaf, tree_axes, tree_specs,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def _mem_record(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    return {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "peak_bytes": (ma.argument_size_in_bytes + ma.temp_size_in_bytes +
                       ma.output_size_in_bytes),
    }


# ---------------------------------------------------------------------------
# case builders: return (fn, args, in_shardings)

def _case_train(cfg, shape, mesh, unroll=False, ce_impl="dense",
                remat=True, slot_remat=False):
    opt_cfg = AdamWConfig()
    step = make_train_step(cfg, opt_cfg, unroll=unroll, ce_impl=ce_impl,
                           remat=remat, slot_remat=slot_remat)
    p_specs, p_axes = S.model_state_specs(cfg)
    o_specs = S.opt_state_specs(p_specs)
    o_axes = S.opt_logical_axes(p_axes)
    b_specs, b_axes = S.batch_inputs(cfg, shape)
    args = [p_specs, o_specs, b_specs["tokens"]]
    shards = [_shardings(p_specs, p_axes, mesh),
              _shardings(o_specs, o_axes, mesh),
              _shardings(b_specs, b_axes, mesh)["tokens"]]
    if cfg.prefix_tokens:
        args.append(b_specs["prefix"])
        shards.append(_shardings(b_specs, b_axes, mesh)["prefix"])
    return step, tuple(args), tuple(shards)


def _case_prefill(cfg, shape, mesh, unroll=False):
    window = decode_window(cfg, shape)
    step = make_prefill_step(cfg, max_seq=shape.seq_len, window=window,
                             unroll=unroll)
    p_specs, p_axes = S.model_state_specs(cfg)
    b_specs, b_axes = S.batch_inputs(cfg, shape)
    args = [p_specs, b_specs["tokens"]]
    shards = [_shardings(p_specs, p_axes, mesh),
              _shardings(b_specs, b_axes, mesh)["tokens"]]
    if cfg.prefix_tokens:
        args.append(b_specs["prefix"])
        shards.append(_shardings(b_specs, b_axes, mesh)["prefix"])
    return step, tuple(args), tuple(shards)


def _case_decode(cfg, shape, mesh, unroll=False):
    window = decode_window(cfg, shape)
    step = make_decode_step(cfg, window=window, unroll=unroll)
    p_specs, p_axes = S.model_state_specs(cfg)
    c_specs, c_axes = S.cache_specs(cfg, shape)
    token = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    args = (p_specs, token, c_specs, pos)
    shards = (_shardings(p_specs, p_axes, mesh),
              jax.NamedSharding(mesh, shd.logical_to_spec(
                  ("batch", None), mesh, token.shape)),
              _shardings(c_specs, c_axes, mesh),
              jax.NamedSharding(mesh, shd.logical_to_spec((), mesh)))
    return step, args, shards


def _case_dml(cfg, shape, mesh, n_clients=2, fused=True, unroll=False,
              sparse_k=0):
    """The paper's technique on the mesh: clients = pod axis."""
    opt_cfg = AdamWConfig()
    step = (dml.make_dml_train_step(cfg, opt_cfg, unroll=unroll,
                                    sparse_k=sparse_k,
                                    spmd_client_axis="pod") if fused
            else dml.make_mutual_step(cfg, opt_cfg, unroll=unroll,
                                      sparse_k=sparse_k,
                                      spmd_client_axis="pod"))
    p_one, p_axes_one = S.model_state_specs(cfg)
    p_specs = jax.tree.map(
        lambda sd: jax.ShapeDtypeStruct((n_clients,) + sd.shape, sd.dtype),
        p_one)
    p_axes = jax.tree.map(
        lambda t: ("client",) + t, p_axes_one,
        is_leaf=lambda x: isinstance(x, tuple) and not isinstance(x, dict))
    o_specs = S.opt_state_specs(p_specs)
    o_axes = S.opt_logical_axes(p_axes)
    pub_b = max(1, shape.global_batch // (4 * n_clients))
    pub_specs, pub_axes = S.public_inputs(cfg, shape, pub_b)
    args = [p_specs, o_specs]
    shards = [_shardings(p_specs, p_axes, mesh),
              _shardings(o_specs, o_axes, mesh)]
    if fused:
        b_specs, b_axes = S.batch_inputs(cfg, shape, n_clients=n_clients)
        args.append(b_specs["tokens"])
        shards.append(_shardings(b_specs, b_axes, mesh)["tokens"])
    args.append(pub_specs["public_tokens"])
    shards.append(_shardings(pub_specs, pub_axes, mesh)["public_tokens"])
    if cfg.prefix_tokens:
        # signature order: (..., tokens, public_tokens, prefix, public_prefix)
        if fused:
            args.append(b_specs["prefix"])
            shards.append(_shardings(b_specs, b_axes, mesh)["prefix"])
        args.append(pub_specs["public_prefix"])
        shards.append(_shardings(pub_specs, pub_axes, mesh)["public_prefix"])
    return step, tuple(args), tuple(shards)


def _case_fedavg_sync(cfg, shape, mesh, n_clients=2, unroll=False):
    """Baseline collective: all-reduce(params) over the client/pod axis."""
    p_one, p_axes_one = S.model_state_specs(cfg)
    p_specs = jax.tree.map(
        lambda sd: jax.ShapeDtypeStruct((n_clients,) + sd.shape, sd.dtype),
        p_one)
    p_axes = jax.tree.map(
        lambda t: ("client",) + t, p_axes_one,
        is_leaf=lambda x: isinstance(x, tuple) and not isinstance(x, dict))
    return dml.fedavg_sync, (p_specs,), (_shardings(p_specs, p_axes, mesh),)


def build_case(cfg, shape, mesh, method: str, unroll: bool = False,
               variant: str = "baseline"):
    ce_impl = "chunked" if "chunked_ce" in variant else "dense"
    remat = "noremat" not in variant
    slot_remat = "slotremat" in variant
    if method == "standard":
        if shape.kind == "train":
            return _case_train(cfg, shape, mesh, unroll, ce_impl=ce_impl,
                               remat=remat, slot_remat=slot_remat)
        if shape.kind == "prefill":
            return _case_prefill(cfg, shape, mesh, unroll)
        return _case_decode(cfg, shape, mesh, unroll)
    sparse_k = 64 if "sparse" in variant else 0
    if method == "dml":
        return _case_dml(cfg, shape, mesh, fused=True, unroll=unroll,
                         sparse_k=sparse_k)
    if method == "mutual":
        return _case_dml(cfg, shape, mesh, fused=False, unroll=unroll,
                         sparse_k=sparse_k)
    if method == "fedavg_sync":
        return _case_fedavg_sync(cfg, shape, mesh)
    raise ValueError(method)


# ---------------------------------------------------------------------------

def cost_dict(compiled) -> Dict[str, float]:
    """compiled.cost_analysis() across jax versions (list-of-dicts before 0.6)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _costs(compiled) -> Dict[str, float]:
    cost = cost_dict(compiled)
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": collective_stats(compiled.as_text())}


def _lower_compile(step, args, in_shardings, mesh):
    with shd.use_mesh(mesh):
        lowered = jax.jit(step, in_shardings=in_shardings).lower(*args)
        return lowered.compile()


def depth_corrected_costs(cfg, shape, mesh, method,
                          variant: str = "baseline") -> Dict[str, Any]:
    """XLA's cost analysis counts a scan body ONCE regardless of trip count,
    so the scanned lowering under-counts per-layer work.  We therefore lower
    two small UNROLLED variants (1 and 2 periods) and extrapolate:

        X_total = X(1) + (n_periods - 1) * (X(2) - X(1))

    which is exact for depth-linear quantities (flops, bytes, collective
    traffic): X(1) carries the embed/head/optimizer constant term.
    """
    P = len(cfg.period)
    cost = {}
    for tag, depth in (("d1", P), ("d2", 2 * P)):
        cc = cfg.replace(n_layers=depth)
        step, args, shards = build_case(cc, shape, mesh, method, unroll=True,
                                        variant=variant)
        compiled = _lower_compile(step, args, shards, mesh)
        cost[tag] = _costs(compiled)
    n = cfg.n_periods
    out: Dict[str, Any] = {}
    for key in ("flops", "bytes"):
        d = max(cost["d2"][key] - cost["d1"][key], 0.0)
        out[key] = cost["d1"][key] + (n - 1) * d
    coll = {}
    for k in cost["d1"]["coll"]:
        d = max(cost["d2"]["coll"][k] - cost["d1"]["coll"][k], 0)
        coll[k] = cost["d1"]["coll"][k] + (n - 1) * d
    out["coll"] = coll
    return out


def model_flops_estimate(cfg, shape, method: str = "standard") -> float:
    """Useful model FLOPs for one step of (cfg, shape, method).

    The classic parameter-FLOP model: a forward pass costs 2·N·D (N =
    active params, D = tokens) and training costs 6·N·D — forward AND
    backward, since every kernel on the hot path (attention, SSD,
    mutual-KL) now carries a custom VJP and trains through the same impl
    it runs forward.  Decode shapes process one token per step; the DML /
    mutual methods add the public-batch mutual phase (trained, so 6·N·D)
    for k = 2 clients; fedavg_sync moves no tokens at all.
    """
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind != "decode" else 1)
    if method == "fedavg_sync":
        tokens = 0
    active = cfg.active_param_count()
    flops_per_tok = 6 * active if shape.kind == "train" else 2 * active
    model_flops = float(flops_per_tok) * tokens
    if method in ("dml", "mutual"):
        k = 2
        pub = max(1, shape.global_batch // (4 * k)) * shape.seq_len
        extra = 6.0 * active * pub * k        # mutual phase is trained
        model_flops = (model_flops if method == "dml" else 0.0) + extra
    return model_flops


def run_case(arch: str, shape_name: str, mesh_kind: str,
             method: str = "standard", verbose: bool = True,
             skip_depth_correction: bool = False,
             variant: str = "baseline") -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "method": method, "chips": n_chips, "status": "ok",
        "variant": variant,
    }
    # client modes: the pod axis belongs to the clients, not the batch
    rules = ({"batch": ("data",), "attn_batch": ("data",)}
             if method in ("dml", "mutual", "fedavg_sync") else {})
    if "attn_dp" in variant:
        # reshard attention over model axis too (heads-indivisible archs)
        rules["attn_batch"] = (rules.get("attn_batch", ("pod", "data"))
                               + ("model",))
    if "no_fsdp" in variant:
        rules["embed"] = None          # replicate params over the data axis
    if "seqpar" in variant:
        rules["res_seq"] = "model"     # sequence-parallel residual stream
    try:
        # 1) the REAL deliverable: the full scanned program must lower+compile
        from repro.kernels import ops as kops
        attn_impl = "xla_flash" if "flash" in variant else "ref"
        with shd.axis_rules(rules), kops.use_impl(attn_impl):
            step, args, in_shardings = build_case(cfg, shape, mesh, method,
                                                  variant=variant)
            compiled = _lower_compile(step, args, in_shardings, mesh)
        rec.update(_mem_record(compiled))
        rec["collectives_scanned"] = collective_stats(compiled.as_text())

        # 2) depth-corrected flops/bytes/collectives for the roofline
        with shd.axis_rules(rules), kops.use_impl(attn_impl):
            if method == "fedavg_sync" or skip_depth_correction:
                costs = _costs(compiled)
            else:
                costs = depth_corrected_costs(cfg, shape, mesh, method,
                                              variant)
        rec["flops_per_device"] = costs["flops"]
        rec["bytes_per_device"] = costs["bytes"]
        rec["collectives"] = costs["coll"]

        # 3) roofline terms (seconds) — the shared three-term model
        from repro.analysis.roofline import roofline_terms
        rl = roofline_terms(rec["flops_per_device"], rec["bytes_per_device"],
                            rec["collectives"]["total"])
        rec.update({k: rl[k] for k in ("t_compute", "t_memory",
                                       "t_collective", "dominant")})

        # 4) useful-FLOP ratio (2ND forward, 6ND fwd+bwd — see the helper)
        model_flops = model_flops_estimate(cfg, shape, method)
        rec["model_flops"] = model_flops
        total_hlo = rec["flops_per_device"] * n_chips
        rec["useful_flop_ratio"] = model_flops / total_hlo if total_hlo else 0.0
        rec["compile_s"] = time.time() - t0
    except Exception as e:  # noqa: BLE001 — a failed case is a bug to record
        rec["status"] = "FAIL"
        rec["error"] = f"{type(e).__name__}: {e}"[:500]
        rec["compile_s"] = time.time() - t0
    if verbose:
        if rec["status"] == "ok":
            print(f"[ok] {arch} {shape_name} {mesh_kind} {method} "
                  f"({rec['compile_s']:.0f}s) dominant={rec['dominant']} "
                  f"tc={rec['t_compute']:.4f} tm={rec['t_memory']:.4f} "
                  f"tx={rec['t_collective']:.4f} "
                  f"useful={rec['useful_flop_ratio']:.2f} "
                  f"peakGB={rec['peak_bytes']/2**30:.1f}", flush=True)
        else:
            print(f"[FAIL] {arch} {shape_name} {mesh_kind} {method} "
                  f"({rec['compile_s']:.0f}s) err={rec['error'][:160]}",
                  flush=True)
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--method", default="standard",
                    choices=["standard", "dml", "mutual", "fedavg_sync"])
    ap.add_argument("--all", action="store_true",
                    help="baseline sweep: every arch x shape on --mesh")
    ap.add_argument("--variant", default="baseline",
                    help="optimisation variant: baseline | chunked_ce | "
                         "flash | chunked_ce+flash | noremat ...")
    ap.add_argument("--out", default=None, help="JSONL output path")
    args = ap.parse_args(argv)

    records = []
    if args.all:
        for arch in ARCH_IDS:
            for shape_name in SHAPES:
                records.append(run_case(arch, shape_name, args.mesh,
                                        args.method, variant=args.variant))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        records.append(run_case(args.arch, args.shape, args.mesh,
                                args.method, variant=args.variant))

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "a") as f:
            for r in records:
                f.write(json.dumps(r) + "\n")
    bad = [r for r in records if r["status"] != "ok"]
    print(f"\n{len(records) - len(bad)}/{len(records)} cases lowered+compiled")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
