"""Launchers: mesh, dry-run, CPU train/serve drivers."""
