"""Step builders shared by the CPU drivers and the multi-pod dry-run."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tfm
from repro.optim import AdamWConfig, adamw_init, adamw_update


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    remat: bool = True, window: Optional[int] = None,
                    unroll: bool = False, ce_impl: str = "dense",
                    slot_remat: bool = False):
    """Single-model (non-federated) train step: CE + AdamW."""
    def step(params, opt_state, tokens, prefix=None):
        def loss(p):
            return tfm.loss_fn(p, cfg, tokens, prefix, window=window,
                               remat=remat, unroll=unroll, ce_impl=ce_impl,
                               slot_remat=slot_remat)
        (_, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
        params2, opt2, om = adamw_update(params, grads, opt_state, opt_cfg)
        return params2, opt2, {**metrics, **om}
    return step


def make_prefill_step(cfg: ModelConfig, max_seq: int,
                      window: Optional[int] = None, unroll: bool = False):
    def step(params, tokens, prefix=None):
        return tfm.prefill(params, cfg, tokens, prefix, max_seq=max_seq,
                           window=window, unroll=unroll)
    return step


def make_decode_step(cfg: ModelConfig, window: Optional[int] = None,
                     unroll: bool = False):
    def step(params, token, cache, pos):
        return tfm.decode_step(params, cfg, token, cache, pos, window=window,
                               unroll=unroll)
    return step


def decode_window(cfg: ModelConfig, shape: ShapeConfig) -> Optional[int]:
    """Long-context policy: dense archs use the sliding-window variant at
    500k (DESIGN.md §5); native sub-quadratic archs keep their own setting."""
    if shape.name == "long_500k" and cfg.long_context_variant == "sliding_window":
        return cfg.long_context_window
    return cfg.sliding_window
