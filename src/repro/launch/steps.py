"""Step builders shared by the CPU drivers and the multi-pod dry-run."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tfm
from repro.optim import AdamWConfig, adamw_init, adamw_update


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    remat: bool = True, window: Optional[int] = None,
                    unroll: bool = False, ce_impl: str = "dense",
                    slot_remat: bool = False):
    """Single-model (non-federated) train step: CE + AdamW."""
    def step(params, opt_state, tokens, prefix=None):
        def loss(p):
            return tfm.loss_fn(p, cfg, tokens, prefix, window=window,
                               remat=remat, unroll=unroll, ce_impl=ce_impl,
                               slot_remat=slot_remat)
        (_, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
        params2, opt2, om = adamw_update(params, grads, opt_state, opt_cfg)
        return params2, opt2, {**metrics, **om}
    return step


def make_prefill_step(cfg: ModelConfig, max_seq: int,
                      window: Optional[int] = None, unroll: bool = False):
    def step(params, tokens, prefix=None):
        return tfm.prefill(params, cfg, tokens, prefix, max_seq=max_seq,
                           window=window, unroll=unroll)
    return step


def make_decode_step(cfg: ModelConfig, window: Optional[int] = None,
                     unroll: bool = False):
    def step(params, token, cache, pos):
        return tfm.decode_step(params, cfg, token, cache, pos, window=window,
                               unroll=unroll)
    return step


def sample_token(logits, key, temperature: float = 0.0, top_k: int = 0):
    """One sampled token id per row of ``logits`` (B, V).

    temperature <= 0 is EXACT greedy (argmax, no PRNG consumed at trace
    level but the caller still threads the key so chunked and one-shot
    decodes stay bit-identical); otherwise temperature-scaled categorical
    sampling, optionally restricted to the top-k logits.  temperature and
    top_k are trace-time constants (they key the jit cache).
    """
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / temperature
    if top_k:
        kth = jax.lax.top_k(scaled, top_k)[0][..., -1:]
        scaled = jnp.where(scaled >= kth, scaled, -jnp.inf)
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)


def make_multistep_decode(cfg: ModelConfig, gen_len: int,
                          window: Optional[int] = None,
                          temperature: float = 0.0, top_k: int = 0,
                          unroll: bool = False):
    """``gen_len`` decode steps in ONE jitted program (``lax.scan`` over
    tokens, in-place cache updates at fixed shapes — no per-token Python
    dispatch).

    The returned step takes ``(params, token, cache, pos, key)`` where
    ``token`` (B, 1) is the next token to EMIT (the one sampled from the
    previous logits — after prefill, sample the prefill logits), ``pos``
    is scalar or (B,) per-slot positions of that emission, and ``key`` is
    the sampling PRNG state (split once per step inside the scan, so a
    fixed seed is deterministic and chunked calls chain bit-identically).

    Returns ``(tokens (B, gen_len), logits (B, gen_len, V), cache,
    next_token (B, 1), next_pos, key)`` — token/position/key carry-out
    lets a scheduler chain chunks: feeding them into the next call
    continues exactly where a single longer scan would have been.
    ``logits[:, t]`` are the distribution the (t+1)-th emission was
    sampled from, aligned with the teacher-forced full forward at the
    same absolute positions (the cache-parity tests pin this).
    """
    def step(params, token, cache, pos, key):
        def body(carry, _):
            tok, cache, p, k = carry
            logits, cache = tfm.decode_step(params, cfg, tok, cache, p,
                                            window=window, unroll=unroll)
            k, sub = jax.random.split(k)
            nxt = sample_token(logits, sub, temperature, top_k)
            return (nxt[:, None], cache, p + 1, k), (tok[:, 0], logits)
        (tok, cache, pos, key), (toks, logits) = jax.lax.scan(
            body, (token, cache, pos, key), None, length=gen_len)
        return (toks.T, logits.transpose(1, 0, 2), cache, tok, pos, key)
    return step


def decode_window(cfg: ModelConfig, shape: ShapeConfig) -> Optional[int]:
    """Long-context policy: dense archs use the sliding-window variant at
    500k (DESIGN.md §5); native sub-quadratic archs keep their own setting."""
    if shape.name == "long_500k" and cfg.long_context_variant == "sliding_window":
        return cfg.long_context_window
    return cfg.sliding_window
