"""CPU-runnable batched serving driver: prefill + decode with KV/SSM cache.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m \
      --batch 2 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_reduced
from repro.data.synthetic import make_token_stream
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import transformer as tfm


def greedy_generate(cfg, params, prompts, gen_len: int, prefix=None):
    """prompts: (B, S0) int32.  Returns (B, gen_len) generated ids."""
    B, S0 = prompts.shape
    max_seq = S0 + gen_len + (cfg.prefix_tokens or 0)
    prefill = jax.jit(make_prefill_step(cfg, max_seq=max_seq))
    decode = jax.jit(make_decode_step(cfg))
    args = (params, prompts) if prefix is None else (params, prompts, prefix)
    logits, cache = prefill(*args)
    out = []
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    pos = S0 + (cfg.prefix_tokens or 0)
    for t in range(gen_len):
        out.append(tok[:, 0])
        logits, cache = decode(params, tok, cache, jnp.int32(pos + t))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return jnp.stack(out, axis=1)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="mamba2-780m")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = tfm.init_model(key, cfg)
    prompts = jnp.asarray(make_token_stream(
        args.batch, args.prompt_len, cfg.vocab_size, seed=args.seed))
    prefix = None
    if cfg.prefix_tokens:
        rng = np.random.default_rng(args.seed)
        prefix = jnp.asarray(rng.normal(
            0, 1, (args.batch, cfg.prefix_tokens, cfg.prefix_dim))
            .astype(np.float32))

    t0 = time.time()
    gen = greedy_generate(cfg, params, prompts, args.gen, prefix)
    dt = time.time() - t0
    print(f"arch={args.arch} generated {gen.shape} in {dt:.1f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("sample:", np.asarray(gen[0])[:16].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
