"""Serving CLI: batched ensemble inference over trained Federations.

  # serve a trained population checkpoint, averaging all clients
  PYTHONPATH=src python -m repro.launch.serve --ckpt runs/fed.npz \
      --ensemble average --batch 2 --prompt-len 8 --gen 16

  # no checkpoint: random-init single model (kernel/arch smoke test)
  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m \
      --batch 2 --prompt-len 32 --gen 16

  # continuous batching: more requests than slots, mixed budgets
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b \
      --requests 8 --slots 2

Timing separates WARMUP (first call — includes jit compilation) from
STEADY STATE (recompiled-nothing repeat), each synced with
``block_until_ready``; the steady-state number is the serving rate.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_reduced
from repro.data.synthetic import make_token_stream
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import transformer as tfm
from repro.serve import MODES, ServeEngine


def greedy_generate(cfg, params, prompts, gen_len: int, prefix=None):
    """Legacy per-token Python decode loop — kept as the token-parity
    reference the engine's fused multi-step scan is tested against.
    prompts: (B, S0) int32.  Returns (B, gen_len) generated ids."""
    B, S0 = prompts.shape
    max_seq = S0 + gen_len + (cfg.prefix_tokens or 0)
    prefill = jax.jit(make_prefill_step(cfg, max_seq=max_seq))
    decode = jax.jit(make_decode_step(cfg))
    args = (params, prompts) if prefix is None else (params, prompts, prefix)
    logits, cache = prefill(*args)
    out = []
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    pos = S0 + (cfg.prefix_tokens or 0)
    for t in range(gen_len):
        out.append(tok[:, 0])
        logits, cache = decode(params, tok, cache, jnp.int32(pos + t))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return jnp.stack(out, axis=1)


def _random_prefix(cfg, batch: int, seed: int):
    if not cfg.prefix_tokens:
        return None
    rng = np.random.default_rng(seed)
    return rng.normal(0, 1, (batch, cfg.prefix_tokens, cfg.prefix_dim)
                      ).astype(np.float32)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt", default=None,
                    help="Federation save_state / export_for_serving file; "
                         "omit to serve a random-init --arch model")
    ap.add_argument("--ensemble", choices=MODES, default="average",
                    help="how to serve the K clients of --ckpt")
    ap.add_argument("--client", type=int, default=0,
                    help="client index for --ensemble single")
    ap.add_argument("--arch", choices=ARCH_IDS, default="mamba2-780m",
                    help="arch for random-init serving (no --ckpt)")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=0,
                    help="cache arena length (0 = fit batch args exactly)")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--requests", type=int, default=0,
                    help=">0: continuous-batching mode with this many "
                         "mixed-length requests instead of one fixed batch")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    max_seq = args.max_seq or ((args.prompt_len + args.gen) * 2)
    kw = dict(max_seq=max_seq, slots=max(args.slots, args.batch),
              chunk=args.chunk, temperature=args.temperature,
              top_k=args.top_k, seed=args.seed)
    if args.ckpt:
        eng = ServeEngine.from_checkpoint(
            args.ckpt, mode=args.ensemble, client=args.client, **kw)
        print(f"ckpt={args.ckpt} arch={eng.cfg.name} "
              f"clients={eng.n_checkpoint_clients} mode={eng.mode}")
    else:
        cfg = get_reduced(args.arch)
        params = tfm.init_model(jax.random.PRNGKey(args.seed), cfg)
        eng = ServeEngine(cfg, params, mode="single", **kw)
        print(f"arch={args.arch} random-init mode=single")
    cfg = eng.cfg

    if args.requests:                      # continuous-batching mode
        rng = np.random.default_rng(args.seed)
        budget = max_seq - (cfg.prefix_tokens or 0)
        for i in range(args.requests):
            s0 = int(rng.integers(2, max(3, min(args.prompt_len,
                                                budget - args.gen) + 1)))
            prompt = rng.integers(0, cfg.vocab_size, (s0,)).astype(np.int32)
            pfx = _random_prefix(cfg, 1, args.seed + i)
            eng.submit(prompt, max_new=min(args.gen, budget - s0),
                       prefix=None if pfx is None else pfx[0])
        t0 = time.perf_counter()
        done = eng.run()
        jax.block_until_ready(eng._arena)
        dt = time.perf_counter() - t0
        n_tok = sum(len(v) for v in done.values())
        print(f"served {len(done)} requests over {eng.slots} slots: "
              f"{n_tok} tokens in {dt:.2f}s ({n_tok / dt:.1f} tok/s, "
              f"compile included); dispatches={eng.dispatch_counts()}")
        rid = min(done)
        print(f"sample rid={rid}:", done[rid][:16].tolist())
        return 0

    prompts = np.asarray(make_token_stream(
        args.batch, args.prompt_len, cfg.vocab_size, seed=args.seed))
    prefix = _random_prefix(cfg, args.batch, args.seed)
    n_tok = args.batch * args.gen

    t0 = time.perf_counter()               # warmup: traces + compiles
    gen = eng.generate(prompts, args.gen, prefix=prefix)
    warm = time.perf_counter() - t0
    t0 = time.perf_counter()               # steady state: cached programs
    gen = eng.generate(prompts, args.gen, prefix=prefix)
    steady = time.perf_counter() - t0
    print(f"generated {gen.shape}: warmup {warm:.2f}s "
          f"({n_tok / warm:.1f} tok/s incl. compile), steady {steady:.3f}s "
          f"({n_tok / steady:.1f} tok/s); dispatches/call="
          f"{len(eng.dispatch_log) // 2}")
    print("sample:", gen[0][:16].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
