"""ShapeDtypeStruct stand-ins for every model input — the dry-run contract.

``input_specs(cfg, shape)`` returns (args, logical_axes) pytrees for the
step function of that shape kind; nothing is ever allocated.  Modality
frontends are stubs: VLM/audio archs get a precomputed embedding prefix of
the configured size (DESIGN.md §5), with the token count reduced so the
total sequence length equals the assigned shape.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.steps import decode_window
from repro.models import transformer as tfm

SDS = jax.ShapeDtypeStruct


def token_len(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Text-token count so prefix + tokens == shape.seq_len."""
    if shape.kind == "decode":
        return 1
    assert shape.seq_len > cfg.prefix_tokens, (cfg.name, shape.name)
    return shape.seq_len - cfg.prefix_tokens


def batch_inputs(cfg: ModelConfig, shape: ShapeConfig,
                 n_clients: int = 0) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """(specs, logical_axes) for the data inputs of a train/prefill step.
    n_clients > 0 stacks a leading client axis (DML mode)."""
    B = shape.global_batch
    S = token_len(cfg, shape)
    lead: Tuple[int, ...] = ()
    lax_: Tuple[Optional[str], ...] = ()
    if n_clients:
        assert B % n_clients == 0
        lead, lax_ = (n_clients,), ("client",)
        B = B // n_clients
    specs = {"tokens": SDS(lead + (B, S), jnp.int32)}
    axes = {"tokens": lax_ + ("batch", "seq")}
    if cfg.prefix_tokens:
        specs["prefix"] = SDS(lead + (B, cfg.prefix_tokens, cfg.prefix_dim),
                              cfg.cdtype())
        axes["prefix"] = lax_ + ("batch", "seq", None)
    return specs, axes


def public_inputs(cfg: ModelConfig, shape: ShapeConfig,
                  public_batch: int) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Public mutual-learning batch (shared by all clients — replicated
    over the client axis, sharded over data)."""
    S = token_len(cfg, shape)
    specs = {"public_tokens": SDS((public_batch, S), jnp.int32)}
    axes = {"public_tokens": ("batch", "seq")}
    if cfg.prefix_tokens:
        specs["public_prefix"] = SDS(
            (public_batch, cfg.prefix_tokens, cfg.prefix_dim), cfg.cdtype())
        axes["public_prefix"] = ("batch", "seq", None)
    return specs, axes


def model_state_specs(cfg: ModelConfig, key=None):
    """(param specs, param logical axes) via eval_shape — no allocation."""
    params = jax.eval_shape(lambda: tfm.init_model(jax.random.PRNGKey(0), cfg))
    return params, tfm.logical_axes(cfg)


def opt_state_specs(param_specs):
    from repro.optim import adamw_init
    return jax.eval_shape(adamw_init, param_specs)


def opt_logical_axes(param_axes):
    return {"mu": param_axes, "nu": param_axes, "step": ()}


def cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    window = decode_window(cfg, shape)
    cache = jax.eval_shape(
        lambda: tfm.init_cache(cfg, shape.global_batch, shape.seq_len,
                               window=window))
    return cache, tfm.cache_logical_axes(cfg)
