"""CPU-runnable training driver (reduced configs) — the end-to-end path.

Single-model pretraining, or a federated session through the unified
``repro.api.Federation`` layer: pick a client population with
``--method`` (``dml`` = stacked same-arch LM clients, ``hetero`` = one
arch PER client) and a sharing strategy with ``--strategy``
(``dml`` / ``sparse-dml`` / ``fedavg`` / ``async``).  The same step
builders are what the dry-run lowers for the production mesh, so this
driver doubles as the integration test of the whole stack.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --steps 20
  PYTHONPATH=src python -m repro.launch.train --arch mamba2-780m \
      --method dml --clients 3 --steps 12
  PYTHONPATH=src python -m repro.launch.train --method dml --clients 3 \
      --strategy sparse-dml --sparse-k 64 --steps 8
  PYTHONPATH=src python -m repro.launch.train --method hetero \
      --archs qwen3-4b,mamba2-780m,dbrx-132b --rounds 3 --participation 2
  PYTHONPATH=src python -m repro.launch.train --method hetero \
      --archs qwen3-4b,qwen3-4b --strategy fedavg --rounds 3

Privacy & robustness (prediction-sharing populations): dp-dml clips and
Gaussian-noises every shared payload (``--dp-epsilon`` calibrates the
noise to a target budget), trimmed-/median-dml swap the Eq.-2 mean for a
robust consensus, and ``--byzantine`` injects poisoned clients to attack:

  PYTHONPATH=src python -m repro.launch.train --method hetero \
      --archs qwen3-4b,mamba2-780m --strategy dp-dml --dp-epsilon 4.0
  PYTHONPATH=src python -m repro.launch.train --method hetero \
      --archs qwen3-4b,mamba2-780m,qwen3-4b --strategy median-dml \
      --byzantine 2=sign-flip --rounds 3

Device-sharded DML (one device owns whole clients; the only collective is
the public-logit all-gather — see core.distributed.make_sharded_dml_step):

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  PYTHONPATH=src python -m repro.launch.train --method dml --clients 4 \
      --steps 8 --mesh clients=4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import checkpoint
from repro.configs import ARCH_IDS, get_reduced
from repro.core.strategies import get_strategy


def _make_strategy(args):
    knobs = dict(kl_weight=args.kl_weight, k=args.sparse_k, trim=args.trim,
                 dp_clip=args.dp_clip, dp_delta=args.dp_delta,
                 dp_seed=args.seed)
    if args.strategy == "dp-dml":
        sigma = args.dp_noise
        if args.dp_epsilon:
            from repro.privacy import calibrate_noise
            releases = args.rounds if args.method == "hetero" else args.steps
            sigma = calibrate_noise(args.dp_epsilon, args.dp_delta, releases)
            print(f"calibrated dp noise multiplier: sigma={sigma:.4f} for "
                  f"(eps={args.dp_epsilon}, delta={args.dp_delta}) over "
                  f"{releases} releases")
        knobs["dp_noise_multiplier"] = sigma
    # get_strategy drops whatever knobs the chosen strategy doesn't take
    return get_strategy(args.strategy, **knobs)


def _parse_byzantine(spec: str) -> dict:
    """``"2=collude,0=sign-flip"`` -> {2: "collude", 0: "sign-flip"}."""
    out = {}
    for item in (spec or "").split(","):
        item = item.strip()
        if not item:
            continue
        idx, _, mode = item.partition("=")
        if not mode:
            raise SystemExit(
                f"--byzantine entries are IDX=MODE, got {item!r}")
        out[int(idx)] = mode
    return out


def _print_history(h) -> None:
    for rl in h.rounds:
        print(f"round {rl.round:3d} participants={rl.participants} "
              f"loss={['%.3f' % x for x in rl.client_loss]} "
              f"kld={['%.4f' % x for x in rl.kl_loss]} "
              f"comm_bytes={rl.comm_bytes}", flush=True)
    print(f"total_comm_bytes={h.total_comm_bytes}")


def _run_hetero(args) -> int:
    """Heterogeneous-client federation (one arch per client)."""
    from repro.api import Federation, HeteroClients, make_lm_pool

    archs = tuple(a.strip() for a in args.archs.split(",") if a.strip())
    vocab = get_reduced(archs[0]).vocab_size
    n_folds = (1 + len(archs)) * args.rounds + 1
    pool, labels = make_lm_pool(n_folds * max(2 * args.batch, 8),
                                args.seq, vocab, seed=args.seed)
    t0 = time.time()
    population = HeteroClients(
        archs, pool, labels, rounds=args.rounds, batch_size=args.batch,
        public_batch=max(1, args.batch // 2), lr=args.lr, seed=args.seed,
        kernel_impl=args.kernel_impl,
        byzantine=_parse_byzantine(args.byzantine))
    fed = Federation(population, _make_strategy(args),
                     participation=args.participation)
    print(f"federating [{args.strategy}]:", ", ".join(
        f"{a} ({population._models[a].family})" for a in archs))
    if args.resume:
        fed.restore_state(args.resume)
        print(f"resumed from {args.resume} at round {fed.round}")
    h = fed.run(until=args.until)
    _print_history(h)
    if hasattr(fed.strategy, "epsilon"):
        print(f"privacy spent: epsilon={fed.strategy.epsilon():.3f} at "
              f"delta={fed.strategy.dp_delta}")
    fed.evaluate()
    print(f"held-out eval loss per client: "
          f"{['%.3f' % x for x in h.client_eval_loss]}")
    print(f"done in {time.time() - t0:.1f}s")
    if args.save:
        fed.save_state(args.save)
        print(f"saved federated state to {args.save}")
    return 0


def _run_federated_lm(args, cfg) -> int:
    """Stacked same-arch LM clients (LLM-scale fused round programs)."""
    from repro.api import Federation, LMClients

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_client_mesh, parse_mesh_spec
        axes = parse_mesh_spec(args.mesh)
        if set(axes) != {"clients"}:
            raise SystemExit(f"--mesh supports clients=N, got {args.mesh}")
        mesh = make_client_mesh(axes["clients"])
        print(f"sharding {args.clients} clients over {axes['clients']} "
              "devices (all-gather of public logits is the only collective)")
    t0 = time.time()
    population = LMClients(cfg, n_clients=args.clients, rounds=args.steps,
                           batch=args.batch, seq=args.seq, lr=args.lr,
                           seed=args.seed, mesh=mesh,
                           kernel_impl=args.kernel_impl)
    fed = Federation(population, _make_strategy(args),
                     participation=args.participation)
    print(f"model: {cfg.name} x {args.clients} clients "
          f"[{args.strategy} strategy]")
    if args.resume:
        fed.restore_state(args.resume)
        print(f"resumed from {args.resume} at step {fed.round}")
    h = fed.run(until=args.until)
    for rl in h.rounds:
        if rl.round % 5 == 0 or rl.round == args.steps - 1:
            pl_ = np.asarray(rl.client_loss)
            kl = np.asarray(rl.kl_loss)
            print(f"step {rl.round:4d} loss={pl_.mean():.4f} "
                  f"kld_avg={kl.mean():.5f} spread={pl_.std():.4f} "
                  f"comm_bytes={rl.comm_bytes}", flush=True)
    print(f"total_comm_bytes={h.total_comm_bytes}")
    print(f"done in {time.time() - t0:.1f}s")
    if args.save:
        fed.save_state(args.save)
        print(f"saved federated state to {args.save}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-4b")
    ap.add_argument("--method", choices=["single", "dml", "hetero"],
                    default="single",
                    help="single model, stacked same-arch clients (dml), "
                         "or one arch per client (hetero)")
    ap.add_argument("--strategy", default="dml",
                    choices=["dml", "sparse-dml", "fedavg", "async",
                             "dp-dml", "trimmed-dml", "median-dml"],
                    help="what crosses the wire each round "
                         "(federated methods only)")
    ap.add_argument("--sparse-k", type=int, default=64,
                    help="top-k kept per position for --strategy sparse-dml")
    ap.add_argument("--dp-noise", type=float, default=1.0,
                    help="Gaussian noise multiplier sigma for dp-dml "
                         "(std = clip * sigma per shared payload)")
    ap.add_argument("--dp-clip", type=float, default=1.0,
                    help="L2 clip bound on each dp-dml payload")
    ap.add_argument("--dp-delta", type=float, default=1e-5,
                    help="delta of the reported (eps, delta) guarantee")
    ap.add_argument("--dp-epsilon", type=float, default=0.0,
                    help="target epsilon: calibrate --dp-noise to spend "
                         "at most this over the whole schedule "
                         "(overrides --dp-noise)")
    ap.add_argument("--byzantine", default="",
                    metavar="IDX=MODE,...",
                    help="poisoned clients for --method hetero, e.g. "
                         "'2=collude,0=sign-flip' (modes: label-flip, "
                         "sign-flip, collude)")
    ap.add_argument("--trim", type=int, default=1,
                    help="values trimmed per side by --strategy "
                         "trimmed-dml")
    ap.add_argument("--clients", type=int, default=2)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--kl-weight", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kernel-impl", default="auto",
                    choices=["auto", "ref", "interpret", "pallas",
                             "xla_flash"],
                    help="kernel implementation for the hot path: 'auto' "
                         "resolves per backend (pallas on TPU, ref "
                         "elsewhere; REPRO_KERNEL_IMPL overrides)")
    ap.add_argument("--save", default=None, help="checkpoint path")
    ap.add_argument("--mesh", default=None, metavar="clients=N",
                    help="device-shard the DML client axis over a "
                         "'clients' mesh of N devices (CPU: set XLA_FLAGS="
                         "--xla_force_host_platform_device_count first)")
    # hetero-only knobs: one arch PER client; round-based schedule
    ap.add_argument("--archs", default="qwen3-4b,mamba2-780m,dbrx-132b",
                    help="comma-separated arch id per client (hetero)")
    ap.add_argument("--rounds", type=int, default=3,
                    help="federated rounds (hetero)")
    ap.add_argument("--until", type=int, default=0,
                    help="stop after this round/step (0 = run the full "
                         "schedule); with --save this checkpoints "
                         "mid-schedule so a later --resume run (SAME "
                         "schedule) continues bitwise-identically")
    ap.add_argument("--participation", type=int, default=0,
                    help="clients sampled per round, 0 = all")
    ap.add_argument("--resume", default=None,
                    help="restore a --save checkpoint and continue "
                         "(federated methods)")
    args = ap.parse_args(argv)

    if args.method == "hetero":
        return _run_hetero(args)

    cfg = get_reduced(args.arch)
    if args.method == "dml":
        return _run_federated_lm(args, cfg)

    from repro.data.synthetic import make_token_stream
    from repro.launch.steps import make_train_step
    from repro.models import transformer as tfm
    from repro.optim import AdamWConfig, adamw_init
    import jax.numpy as jnp

    opt_cfg = AdamWConfig(lr=args.lr, warmup=5, total_steps=args.steps)
    key = jax.random.PRNGKey(args.seed)

    def batch_for(domain: int, step: int, batch: int):
        toks = make_token_stream(batch, args.seq + 1, cfg.vocab_size,
                                 seed=1000 * step + args.seed, domain=domain)
        out = [jnp.asarray(toks[:, :args.seq])]
        if cfg.prefix_tokens:
            rng = np.random.default_rng(step)
            out.append(jnp.asarray(rng.normal(
                0, 1, (batch, cfg.prefix_tokens, cfg.prefix_dim))
                .astype(np.float32)))
        return out

    t0 = time.time()
    params = tfm.init_model(key, cfg)
    opt = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))
    for i in range(args.steps):
        params, opt, m = step_fn(params, opt, *batch_for(0, i, args.batch))
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:4d} ce={float(m['ce']):.4f} "
                  f"gnorm={float(m['grad_norm']):.2f}", flush=True)

    print(f"done in {time.time() - t0:.1f}s")
    if args.save:
        checkpoint.save(args.save, params,
                        {"arch": args.arch, "method": args.method,
                         "steps": args.steps})
        print(f"saved checkpoint to {args.save}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
