"""Data substrate: synthetic generators + federated sharding/rotation."""
from repro.data import federated, synthetic  # noqa: F401
