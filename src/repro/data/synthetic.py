"""Synthetic data generators (offline container — see DESIGN.md §2).

Images: two-class 'face-mask-like' generator with a controllable
class-separating signal (class 1 adds a bright patch over the lower-center
region) plus per-source appearance shift, so the three FL frameworks can be
compared on learnability AND cross-dataset generalisation (the paper's
dataset-1-train / dataset-2-test protocol).

Tokens: bigram-structured streams (affine next-token rule with noise) with a
per-domain rule so federated clients can be IID or domain-skewed.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


# ---------------------------------------------------------------------------
# images (the paper's case study)

def make_image_dataset(n: int, image_size: int = 100, seed: int = 0,
                       brightness: float = 0.0, noise: float = 0.25,
                       signal: float = 0.45) -> Tuple[np.ndarray, np.ndarray]:
    """Balanced two-class image set.  Returns (images (n,H,W,3), labels (n,))."""
    rng = np.random.default_rng(seed)
    H = W = image_size
    labels = np.arange(n) % 2
    rng.shuffle(labels)
    base = rng.uniform(0.2, 0.6, size=(n, 1, 1, 3)) + brightness
    imgs = np.clip(base + rng.normal(0, noise, size=(n, H, W, 3)), 0, 1)
    # class-1 signal: bright 'mask' patch over lower-center, soft edges
    y0, y1 = int(0.55 * H), int(0.9 * H)
    x0, x1 = int(0.2 * W), int(0.8 * W)
    patch = rng.normal(signal, 0.08, size=(n, y1 - y0, x1 - x0, 3))
    sel = labels.astype(bool)
    region = imgs[sel, y0:y1, x0:x1, :]
    imgs[sel, y0:y1, x0:x1, :] = np.clip(region + patch[sel], 0, 1)
    return imgs.astype(np.float32), labels.astype(np.int32)


def make_paper_datasets(image_size: int = 100, seed: int = 0,
                        n_train: int = 3833, n_test: int = 5988):
    """Dataset 1 (train, GitHub-like) and Dataset 2 (unseen test, Kaggle-like)
    with a deliberate appearance shift between them (paper Table I sizes)."""
    ds1 = make_image_dataset(n_train, image_size, seed=seed,
                             brightness=0.0, noise=0.25)
    ds2 = make_image_dataset(n_test, image_size, seed=seed + 999,
                             brightness=0.08, noise=0.32)
    return ds1, ds2


# ---------------------------------------------------------------------------
# token streams (LLM-scale path)

def make_token_stream(n_seqs: int, seq_len: int, vocab: int, seed: int = 0,
                      domain: int = 0, noise: float = 0.15) -> np.ndarray:
    """Learnable bigram streams: next = (a*t + b) % vocab with prob 1-noise."""
    rng = np.random.default_rng(seed + 7919 * domain)
    a = 31 + 2 * domain
    b = 7 + domain
    toks = np.empty((n_seqs, seq_len), np.int32)
    toks[:, 0] = rng.integers(0, vocab, n_seqs)
    for t in range(1, seq_len):
        nxt = (a * toks[:, t - 1] + b) % vocab
        rand = rng.integers(0, vocab, n_seqs)
        use_rand = rng.random(n_seqs) < noise
        toks[:, t] = np.where(use_rand, rand, nxt)
    return toks


def batched(arrays, batch_size: int, seed: int = 0, drop_last: bool = True):
    """Shuffled mini-batch iterator over aligned numpy arrays."""
    n = arrays[0].shape[0]
    rng = np.random.default_rng(seed)
    order = rng.permutation(n)
    end = (n // batch_size) * batch_size if drop_last else n
    for i in range(0, end, batch_size):
        idx = order[i: i + batch_size]
        yield tuple(a[idx] for a in arrays)
