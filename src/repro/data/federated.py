"""Federated data plumbing: stratified K-folds (Algorithm 1), client shards,
Dirichlet non-IID splits, the per-round public-set rotation, and the
fixed-shape per-round batch plans the vmapped round engine scans over."""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np


def sample_participants(n_clients: int, participation: int, seed: int,
                        round_idx: int) -> List[int]:
    """The M <= K clients sampled for one round (partial participation).

    Stateless in ``round_idx`` — a resumed run samples exactly the same
    subsets as an uninterrupted one.  ``participation`` <= 0 or >= K means
    everyone.  Shared by both round engines so the same (seed, round)
    always names the same subset across engines.
    """
    M = participation or n_clients
    M = min(M, n_clients)
    if M >= n_clients:
        return list(range(n_clients))
    rng = np.random.default_rng(seed * 9973 + 17 + round_idx)
    return sorted(rng.choice(n_clients, size=M, replace=False).tolist())


def round_batch_indices(folds: Sequence[np.ndarray], local_epochs: int,
                        batch_size: int, seed: int = 0
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """Fixed-shape batch plan for one round of the vmapped round engine.

    ``folds``: one index array per client (possibly ragged).  Returns

      idx  (K, T, B) int64  — gather plan, T = local_epochs * max_c steps_c
                              with steps_c = len(fold_c) // batch_size
      mask (K, T) float32   — 1 where the batch is a real update for that
                              client, 0 where it is shape padding

    Per epoch every client makes one drop-last pass over a fresh
    permutation of its fold — the same batch budget as a per-client Python
    loop.  Clients with fewer examples than the widest client get padding
    steps (cycled indices, masked out of the optimiser update) so the
    whole round is one ``vmap(lax.scan)``-able tensor.
    """
    K = len(folds)
    steps = [len(f) // batch_size for f in folds]
    max_steps = max(steps, default=0)
    T = local_epochs * max_steps
    idx = np.zeros((K, T, batch_size), np.int64)
    mask = np.zeros((K, T), np.float32)
    if T == 0:
        return idx, mask
    rng = np.random.default_rng(seed)
    for c, fold in enumerate(folds):
        if len(fold) == 0:
            continue                       # fully masked; zeros never used
        for e in range(local_epochs):
            perm = fold[rng.permutation(len(fold))]
            t0 = e * max_steps
            idx[c, t0:t0 + max_steps] = np.resize(perm,
                                                  (max_steps, batch_size))
            mask[c, t0:t0 + steps[c]] = 1.0
    return idx, mask


class _RoundPlanMixin:
    """Shared ``pop_round``: K client folds popped in Algorithm-1 order,
    compiled into the fixed-shape (K, T, B) plan above."""

    def pop_round(self, n_clients: int, local_epochs: int, batch_size: int,
                  seed: int = 0):
        folds = [self.pop() for _ in range(n_clients)]
        idx, mask = round_batch_indices(folds, local_epochs, batch_size,
                                        seed=seed)
        return folds, idx, mask


def stratified_k_folds(labels: np.ndarray, n_folds: int,
                       seed: int = 0) -> List[np.ndarray]:
    """Index folds preserving class balance (paper line 1:
    Fold <- (1+Clients) x Rounds + 1)."""
    rng = np.random.default_rng(seed)
    folds: List[List[int]] = [[] for _ in range(n_folds)]
    for cls in np.unique(labels):
        idx = np.flatnonzero(labels == cls)
        rng.shuffle(idx)
        for i, chunk in enumerate(np.array_split(idx, n_folds)):
            folds[i].extend(chunk.tolist())
    out = []
    for f in folds:
        arr = np.array(sorted(f), np.int64)
        rng.shuffle(arr)
        out.append(arr)
    return out


class FoldScheduler(_RoundPlanMixin):
    """Algorithm 1's ``Fold.pop()`` discipline.

    Fold count = (1 + K) * rounds + 1: one fold to initialise the global
    model, then per round one fold per client + one for the global model /
    public mutual-learning set.
    """

    def __init__(self, labels: np.ndarray, n_clients: int, rounds: int,
                 seed: int = 0):
        self.n_folds = (1 + n_clients) * rounds + 1
        self._folds = stratified_k_folds(labels, self.n_folds, seed)
        self._cursor = 0

    def pop(self) -> np.ndarray:
        assert self._cursor < self.n_folds, "fold budget exhausted"
        f = self._folds[self._cursor]
        self._cursor += 1
        return f

    def remaining(self) -> int:
        return self.n_folds - self._cursor

    # fold CONTENTS are deterministic in (labels, K, rounds, seed), so a
    # checkpoint only needs the cursor to resume the rotation exactly
    def state(self) -> dict:
        return {"cursor": self._cursor}

    def load_state(self, st: dict) -> None:
        self._cursor = int(st["cursor"])


class NonIIDScheduler(_RoundPlanMixin):
    """Fold discipline with Dirichlet(alpha) class skew per client
    (the paper's §VI future-work setting).

    Pop-order compatible with Algorithm 1 / FoldScheduler: one shared
    (public/global) fold at init, then per round K client folds followed by
    one shared fold.  Shared folds stay class-balanced (the server's public
    set is public data); each client's folds are drawn from its own skewed
    shard, split across rounds.
    """

    def __init__(self, labels: np.ndarray, n_clients: int, rounds: int,
                 alpha: float = 0.3, seed: int = 0):
        self.n_folds = (1 + n_clients) * rounds + 1
        self.n_clients = n_clients
        self.rounds = rounds
        rng = np.random.default_rng(seed)
        n = len(labels)
        # hold out a balanced pool for the (rounds + 1) shared folds
        shared_pool_size = n * (rounds + 1) // self.n_folds
        order = rng.permutation(n)
        shared_pool, client_pool = order[:shared_pool_size], order[shared_pool_size:]
        shared_folds = stratified_k_folds(labels[shared_pool], rounds + 1,
                                          seed)
        self._shared = [shared_pool[f] for f in shared_folds]
        shards = dirichlet_shards(labels[client_pool], n_clients, alpha,
                                  seed + 1)
        self._client = []
        for shard in shards:
            idx = client_pool[shard]
            rng.shuffle(idx)
            self._client.append(np.array_split(idx, rounds))
        self._round = 0
        self._pos = 0            # 0 = next pop is shared-init / post-round
        self._init_done = False

    def pop(self) -> np.ndarray:
        if not self._init_done:
            self._init_done = True
            return self._shared[0]
        assert self._round < self.rounds, "fold budget exhausted"
        if self._pos < self.n_clients:
            f = self._client[self._pos][self._round]
            self._pos += 1
            return f
        f = self._shared[1 + self._round]
        self._round += 1
        self._pos = 0
        return f

    def remaining(self) -> int:
        used = 1 if self._init_done else 0
        used += self._round * (self.n_clients + 1) + self._pos
        return self.n_folds - used

    def state(self) -> dict:
        return {"round": self._round, "pos": self._pos,
                "init_done": self._init_done}

    def load_state(self, st: dict) -> None:
        self._round = int(st["round"])
        self._pos = int(st["pos"])
        self._init_done = bool(st["init_done"])


def dirichlet_shards(labels: np.ndarray, n_clients: int, alpha: float,
                     seed: int = 0) -> List[np.ndarray]:
    """Non-IID client shards via per-class Dirichlet allocation."""
    rng = np.random.default_rng(seed)
    shards: List[List[int]] = [[] for _ in range(n_clients)]
    for cls in np.unique(labels):
        idx = np.flatnonzero(labels == cls)
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props)[:-1] * len(idx)).astype(int)
        for shard, part in zip(shards, np.split(idx, cuts)):
            shard.extend(part.tolist())
    return [np.array(sorted(s), np.int64) for s in shards]


def iid_shards(n: int, n_clients: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    order = rng.permutation(n)
    return [np.sort(part) for part in np.array_split(order, n_clients)]


def public_round_sets(labels: np.ndarray, rounds: int,
                      per_round: int, seed: int = 0) -> List[np.ndarray]:
    """Rotating public test sets — 'dynamically changing test dataset
    provided by the central server ... varies in each round' (paper §III.A)."""
    folds = stratified_k_folds(labels, rounds, seed)
    return [f[:per_round] for f in folds]
