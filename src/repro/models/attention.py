"""GQA attention block: train/prefill forward + ring-buffer KV-cache decode.

Features (per assigned architectures): grouped KV heads, optional per-head
qk RMS-norm (qwen3), optional QKV bias (qwen1.5), optional sliding window
(mistral / long-context variants).  The KV cache is a ring buffer of size
min(max_seq, window): sliding-window decode at 500k context stores only the
window.  Absolute positions are cached alongside K/V so RoPE'd keys stay
valid after wrap-around.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models.layers import apply_rope, dense_init, rms_norm
from repro.sharding import constrain


def init_attention(key, cfg: ModelConfig):
    hd = cfg.head_dim_
    n_qkv = cfg.n_heads + 2 * cfg.n_kv_heads
    keys = jax.random.split(key, 3)
    p = {
        "w_qkv": dense_init(keys[0], (cfg.d_model, n_qkv, hd), cfg.pdtype()),
        "w_o": dense_init(keys[1], (cfg.n_heads, hd, cfg.d_model), cfg.pdtype(),
                          scale=(cfg.n_heads * hd) ** -0.5),
    }
    if cfg.qkv_bias:
        p["b_qkv"] = jnp.zeros((n_qkv, hd), cfg.pdtype())
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), cfg.pdtype())
        p["k_norm"] = jnp.zeros((hd,), cfg.pdtype())
    return p


def attention_logical_axes(cfg: ModelConfig):
    ax = {"w_qkv": ("embed", "heads", "head_dim"),
          "w_o": ("heads", "head_dim", "embed")}
    if cfg.qkv_bias:
        ax["b_qkv"] = ("heads", "head_dim")
    if cfg.qk_norm:
        ax["q_norm"] = ("head_dim",)
        ax["k_norm"] = ("head_dim",)
    return ax


def _project_qkv(params, cfg: ModelConfig, x, positions):
    qkv = jnp.einsum("bsd,dnh->bsnh", x, params["w_qkv"])
    if cfg.qkv_bias:
        qkv = qkv + params["b_qkv"]
    q = qkv[:, :, : cfg.n_heads]
    k = qkv[:, :, cfg.n_heads: cfg.n_heads + cfg.n_kv_heads]
    v = qkv[:, :, cfg.n_heads + cfg.n_kv_heads:]
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.rms_eps)
        k = rms_norm(k, params["k_norm"], cfg.rms_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_forward(params, cfg: ModelConfig, x, positions=None,
                      window: Optional[int] = None,
                      impl: Optional[str] = None):
    """Self-attention over x (B, S, d).  window=None -> cfg.sliding_window.

    ``impl`` selects the kernel implementation (see ``kernels.ops``);
    None defers to the ambient default — production populations pass the
    impl they resolved at construction.  Every impl is differentiable
    (the flash kernel carries a custom VJP), so training steps thread the
    SAME impl they run forward.
    """
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    if window is None:
        window = cfg.sliding_window
    q, k, v = _project_qkv(params, cfg, x, positions)
    q = constrain(q, "attn_batch", "seq", "heads", None)
    k = constrain(k, "attn_batch", "seq", "kv_heads", None)
    v = constrain(v, "attn_batch", "seq", "kv_heads", None)
    out = ops.attention(q, k, v, causal=True, window=window, impl=impl)
    out = constrain(out, "attn_batch", "seq", "heads", None)
    return jnp.einsum("bsnh,nhd->bsd", out, params["w_o"])


# ---------------------------------------------------------------------------
# KV cache (decode)

def init_kv_cache(cfg: ModelConfig, batch: int, max_seq: int,
                  window: Optional[int] = None, dtype=None):
    """Ring-buffer cache for ONE attention layer."""
    dtype = dtype or cfg.cdtype()
    size = max_seq if window is None else min(window, max_seq)
    hd = cfg.head_dim_
    return {
        "k": jnp.zeros((batch, size, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, size, cfg.n_kv_heads, hd), dtype),
        "pos": jnp.full((batch, size), -1, jnp.int32),
    }


def kv_cache_logical_axes():
    return {"k": ("batch", "kv_seq", "kv_heads", "head_dim"),
            "v": ("batch", "kv_seq", "kv_heads", "head_dim"),
            "pos": ("batch", "kv_seq")}


def attention_decode(params, cfg: ModelConfig, x, cache, pos,
                     window: Optional[int] = None):
    """One-token decode.  x: (B, 1, d); pos: scalar int32 (tokens so far),
    or a (B,) vector of PER-SEQUENCE positions (the serving arena: slots
    admitted mid-flight sit at heterogeneous depths).

    Returns (y (B, 1, d), updated cache).
    """
    B = x.shape[0]
    if window is None:
        window = cfg.sliding_window
    pos = jnp.asarray(pos)
    per_slot = pos.ndim == 1
    positions = (pos[:, None] if per_slot
                 else jnp.broadcast_to(pos[None, None], (B, 1))
                 ).astype(jnp.int32)
    q, k, v = _project_qkv(params, cfg, x, positions)

    size = cache["k"].shape[1]
    slot = (pos % size).astype(jnp.int32)
    if per_slot:
        bidx = jnp.arange(B)
        k_cache = cache["k"].at[bidx, slot].set(k[:, 0].astype(cache["k"].dtype))
        v_cache = cache["v"].at[bidx, slot].set(v[:, 0].astype(cache["v"].dtype))
        pos_cache = cache["pos"].at[bidx, slot].set(positions[:, 0])
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
        pos_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32), slot, axis=1)
    k_cache = constrain(k_cache, "batch", "kv_seq", "kv_heads", None)
    v_cache = constrain(v_cache, "batch", "kv_seq", "kv_heads", None)

    out = ops.attention(q, k_cache, v_cache, causal=True, window=window,
                        positions_q=positions, positions_k=pos_cache)
    y = jnp.einsum("bsnh,nhd->bsd", out, params["w_o"])
    return y, {"k": k_cache, "v": v_cache, "pos": pos_cache}


def attention_prefill(params, cfg: ModelConfig, x, cache,
                      window: Optional[int] = None):
    """Prompt ingestion: full self-attention + cache write.

    x: (B, S, d).  Fills the (ring) cache with the last ``size`` positions.
    Returns (y (B, S, d), cache).
    """
    B, S, _ = x.shape
    if window is None:
        window = cfg.sliding_window
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _project_qkv(params, cfg, x, positions)
    q = constrain(q, "attn_batch", "seq", "heads", None)
    out = ops.attention(q, k, v, causal=True, window=window)
    y = jnp.einsum("bsnh,nhd->bsd", out, params["w_o"])

    size = cache["k"].shape[1]
    if S >= size:
        # keep the trailing window; ring slot of absolute position p is p % size
        tail_pos = jnp.arange(S - size, S)
        shift = (S - size) % size if size else 0
        roll = lambda a: jnp.roll(a, shift=shift, axis=1)
        k_keep = roll(k[:, S - size:].astype(cache["k"].dtype))
        v_keep = roll(v[:, S - size:].astype(cache["v"].dtype))
        p_keep = roll(jnp.broadcast_to(tail_pos, (B, size)).astype(jnp.int32))
        cache = {"k": k_keep, "v": v_keep, "pos": p_keep}
    else:
        k_cache = cache["k"].at[:, :S].set(k.astype(cache["k"].dtype))
        v_cache = cache["v"].at[:, :S].set(v.astype(cache["v"].dtype))
        p_cache = cache["pos"].at[:, :S].set(positions.astype(jnp.int32))
        cache = {"k": k_cache, "v": v_cache, "pos": p_cache}
    cache = {"k": constrain(cache["k"], "batch", "kv_seq", "kv_heads", None),
             "v": constrain(cache["v"], "batch", "kv_seq", "kv_heads", None),
             "pos": cache["pos"]}
    return y, cache
