"""Model zoo + the per-client model registry for heterogeneous federation.

The zoo itself is the shared decoder backbone (``models.transformer``
assembling attention / Mamba / MoE slots) plus the paper's VisionNet CNN.
``get_client_model`` wraps any of them behind one small interface so the
heterogeneous engine (``core.hetero``) can federate clients whose pytrees
do not even match: every client exposes init / private-loss /
public-CE-and-logits / share-logits, and only the shared (N_pub, V) logits
ever cross a client boundary.

Two modalities ("kind"):
  - 'lm':     token streams; V = vocab_size.  Families dense / ssm / moe /
              hybrid, resolved through the config registry by arch id.
  - 'vision': the paper's VisionNet; the Bernoulli head is lifted to
              2-class logits [log(1-p), log p] so the categorical Eq.-2
              machinery applies unchanged (softmax == [1-p, p], and the
              categorical KL equals the Bernoulli KL exactly).

A single federation must share one kind and one prediction space V — that
is the whole point of prediction sharing: it composes across model
families, but only over a common public set.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import transformer, visionnet  # noqa: F401


class ClientModel(NamedTuple):
    """One federated client's model, behind the modality-uniform interface.

    All callables take gathered arrays (inputs, labels) so the engine can
    drive any family identically; ``labels`` is ignored by 'lm' clients
    (next-token targets come from the stream itself).
    """
    arch: str                     # registry id ('qwen3-4b', 'visionnet', ...)
    family: str                   # dense | ssm | moe | hybrid | vision
    kind: str                     # 'lm' | 'vision'
    cfg: Any
    init: Callable                # key -> params
    private_loss: Callable        # (params, inputs, labels, key) -> scalar
    public_ce_and_logits: Callable  # (params, inputs, labels, key)
    #                                   -> (ce, logits (N_pub, V))
    share_logits: Callable        # (params, inputs) -> (N_pub, V), eval mode
    n_classes: int                # V of the shared prediction space


def _lm_client(arch: str, cfg) -> ClientModel:
    V = cfg.vocab_size

    def private_loss(params, tokens, labels, key):
        del labels, key                      # targets are the shifted stream
        loss, _ = transformer.loss_fn(params, cfg, tokens)
        return loss

    def public_ce_and_logits(params, tokens, labels, key):
        del labels, key
        logits, _ = transformer.forward(params, cfg, tokens)
        logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
        ce = -jnp.mean(jnp.take_along_axis(logp, tokens[:, 1:, None], -1))
        return ce, logits.reshape(-1, V)

    def share_logits(params, tokens):
        logits, _ = transformer.forward(params, cfg, tokens)
        return logits.reshape(-1, V)

    return ClientModel(arch, cfg.family, "lm", cfg,
                       lambda key: transformer.init_model(key, cfg),
                       private_loss, public_ce_and_logits, share_logits, V)


def _bern_to_logits(p):
    """(B,) sigmoid prob -> (B, 2) logits with softmax exactly [1-p, p]."""
    p = jnp.clip(p.astype(jnp.float32), 1e-6, 1 - 1e-6)
    return jnp.stack([jnp.log1p(-p), jnp.log(p)], axis=-1)


def _vision_client(arch: str, cfg) -> ClientModel:
    def private_loss(params, images, labels, key):
        probs = visionnet.visionnet_forward(params, cfg, images, train=True,
                                            dropout_key=key)
        return visionnet.bce_loss(probs, labels)

    def public_ce_and_logits(params, images, labels, key):
        probs = visionnet.visionnet_forward(params, cfg, images, train=True,
                                            dropout_key=key)
        return visionnet.bce_loss(probs, labels), _bern_to_logits(probs)

    def share_logits(params, images):
        return _bern_to_logits(
            visionnet.visionnet_forward(params, cfg, images, train=False))

    return ClientModel(arch, "vision", "vision", cfg,
                       lambda key: visionnet.init_visionnet(key, cfg),
                       private_loss, public_ce_and_logits, share_logits, 2)


def get_client_model(arch: str, reduced: bool = True) -> ClientModel:
    """Resolve an arch id to its family-specific client interface."""
    if arch == "visionnet":
        from repro.configs import visionnet as vn_cfg
        return _vision_client(arch, vn_cfg.reduced() if reduced
                              else vn_cfg.CONFIG)
    from repro.configs import get_config, get_reduced
    cfg = get_reduced(arch) if reduced else get_config(arch)
    if cfg.prefix_tokens:
        raise ValueError(
            f"{arch}: modality-frontend archs (prefix_tokens > 0) are not "
            "supported as heterogeneous clients — the public set is a plain "
            "token stream")
    return _lm_client(arch, cfg)
