"""Model zoo: shared decoder backbone + the paper's VisionNet CNN."""
from repro.models import transformer, visionnet  # noqa: F401
