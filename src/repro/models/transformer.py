"""Decoder backbone assembling attention / Mamba / MLP / MoE slots.

Layers are organised as ``n_periods`` repetitions of ``cfg.period`` (the
repeating unit: 1 slot for dense/MoE/SSM models, 8 for Jamba's 1:7 hybrid).
Period parameters are stacked on a leading axis and the stack is traversed
with ``lax.scan`` so 80-layer models lower to compact HLO; the period body
is optionally ``jax.checkpoint``-ed (activation remat).

Modes:
  - forward / loss:   training and the faithful-reproduction path
  - prefill:          prompt ingestion -> (last-token logits, cache)
  - decode_step:      one token against the cache (KV ring / SSM state)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (apply_mlp, dense_init, embed_init, init_mlp,
                                 mlp_logical_axes, rms_norm)
from repro.sharding import constrain

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init

def _init_slot(key, cfg: ModelConfig, spec):
    keys = jax.random.split(key, 2)
    p: Params = {"norm1": jnp.zeros((cfg.d_model,), cfg.pdtype())}
    if spec.mixer == "attn":
        p["mixer"] = attn_mod.init_attention(keys[0], cfg)
    else:
        p["mixer"] = ssm_mod.init_mamba(keys[0], cfg)
    if spec.ffn != "none":
        p["norm2"] = jnp.zeros((cfg.d_model,), cfg.pdtype())
        if spec.ffn == "mlp":
            p["ffn"] = init_mlp(keys[1], cfg.d_model, cfg.d_ff, cfg.pdtype())
        else:
            p["ffn"] = moe_mod.init_moe(keys[1], cfg)
    return p


def _init_period(key, cfg: ModelConfig):
    keys = jax.random.split(key, len(cfg.period))
    return {f"slot{i}": _init_slot(keys[i], cfg, spec)
            for i, spec in enumerate(cfg.period)}


def init_model(key, cfg: ModelConfig) -> Params:
    k_embed, k_periods, k_head, k_proj = jax.random.split(key, 4)
    params: Params = {
        "embed": embed_init(k_embed, (cfg.vocab_size, cfg.d_model), cfg.pdtype()),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.pdtype()),
    }
    period_keys = jax.random.split(k_periods, cfg.n_periods)
    params["periods"] = jax.vmap(
        functools.partial(_init_period, cfg=cfg))(period_keys)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, (cfg.d_model, cfg.vocab_size),
                                       cfg.pdtype())
    if cfg.prefix_tokens:
        params["projector"] = {
            "w": dense_init(k_proj, (cfg.prefix_dim, cfg.d_model), cfg.pdtype()),
            "b": jnp.zeros((cfg.d_model,), cfg.pdtype()),
        }
    return params


def _slot_logical_axes(cfg: ModelConfig, spec):
    ax: Params = {"norm1": ("embed_act",)}
    if spec.mixer == "attn":
        ax["mixer"] = attn_mod.attention_logical_axes(cfg)
    else:
        ax["mixer"] = ssm_mod.mamba_logical_axes(cfg)
    if spec.ffn != "none":
        ax["norm2"] = ("embed_act",)
        ax["ffn"] = (mlp_logical_axes() if spec.ffn == "mlp"
                     else moe_mod.moe_logical_axes(cfg))
    return ax


def logical_axes(cfg: ModelConfig) -> Params:
    """Pytree of logical-axis tuples parallel to ``init_model``'s output."""
    period_ax = {f"slot{i}": _slot_logical_axes(cfg, spec)
                 for i, spec in enumerate(cfg.period)}
    # add the stacked 'layers' axis on every period leaf
    period_ax = jax.tree.map(
        lambda t: ("layers",) + t, period_ax,
        is_leaf=lambda x: isinstance(x, tuple) and not isinstance(x, dict))
    ax: Params = {
        "embed": ("vocab", "embed"),
        "final_norm": ("embed_act",),
        "periods": period_ax,
    }
    if not cfg.tie_embeddings:
        ax["lm_head"] = ("embed", "vocab")
    if cfg.prefix_tokens:
        ax["projector"] = {"w": (None, "embed"), "b": ("embed_act",)}
    return ax


# ---------------------------------------------------------------------------
# forward (train / eval)

def _apply_slot(params, cfg: ModelConfig, spec, x, positions,
                window: Optional[int], impl: Optional[str] = None):
    aux = {"load_balance": jnp.zeros((), jnp.float32),
           "router_z": jnp.zeros((), jnp.float32)}
    h = rms_norm(x, params["norm1"], cfg.rms_eps)
    if spec.mixer == "attn":
        h = attn_mod.attention_forward(params["mixer"], cfg, h, positions,
                                       window=window, impl=impl)
    else:
        h = ssm_mod.mamba_forward(params["mixer"], cfg, h, impl=impl)
    x = x + h
    if spec.ffn != "none":
        h = rms_norm(x, params["norm2"], cfg.rms_eps)
        if spec.ffn == "mlp":
            h = apply_mlp(params["ffn"], h)
        else:
            h, aux = moe_mod.apply_moe(params["ffn"], cfg, h)
        x = x + h
    x = constrain(x, "batch", "res_seq", "embed_act")
    return x, aux


def _embed(params, cfg: ModelConfig, tokens, prefix_emb):
    x = params["embed"].astype(cfg.cdtype())[tokens]
    if cfg.prefix_tokens:
        proj = (prefix_emb.astype(cfg.cdtype()) @ params["projector"]["w"]
                + params["projector"]["b"]).astype(x.dtype)
        x = jnp.concatenate([proj, x], axis=1)
    return constrain(x, "batch", "res_seq", "embed_act")


def _unembed(params, cfg: ModelConfig, x):
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x @ head.astype(x.dtype)
    return constrain(logits, "batch", "seq", "vocab")


def forward_hidden(params, cfg: ModelConfig, tokens, prefix_emb=None, *,
                   window: Optional[int] = None, remat: bool = True,
                   unroll: bool = False, slot_remat: bool = False,
                   impl: Optional[str] = None):
    """Backbone only: final hidden states (pre final-norm) + aux losses.
    ``unroll`` replaces the period scan with a Python loop (exact HLO cost
    accounting in the dry-run — see launch/dryrun.py).  ``slot_remat``
    checkpoints every slot individually (multi-slot periods like Jamba's
    8-layer block otherwise keep the whole period's activations live in
    the backward pass).  ``impl`` selects the mixer kernel implementation
    (``kernels.ops``); None defers to the ambient default."""
    x = _embed(params, cfg, tokens, prefix_emb)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def period_body(carry, period_params):
        h = carry
        aux_tot = {"load_balance": jnp.zeros((), jnp.float32),
                   "router_z": jnp.zeros((), jnp.float32)}
        for i, spec in enumerate(cfg.period):
            def slot_fn(p, hh, spec=spec):
                return _apply_slot(p, cfg, spec, hh, positions, window,
                                   impl=impl)
            if slot_remat:
                slot_fn = jax.checkpoint(slot_fn)
            h, aux = slot_fn(period_params[f"slot{i}"], h)
            aux_tot = jax.tree.map(jnp.add, aux_tot, aux)
        return h, aux_tot

    body = (jax.checkpoint(period_body) if (remat and not slot_remat)
            else period_body)
    if unroll:
        aux = {"load_balance": jnp.zeros((), jnp.float32),
               "router_z": jnp.zeros((), jnp.float32)}
        for idx in range(cfg.n_periods):
            pp = jax.tree.map(lambda t, idx=idx: t[idx], params["periods"])
            x, a = body(x, pp)
            aux = jax.tree.map(jnp.add, aux, a)
    else:
        x, auxs = jax.lax.scan(body, x, params["periods"])
        aux = jax.tree.map(jnp.sum, auxs)
    return x, aux


def forward(params, cfg: ModelConfig, tokens, prefix_emb=None, *,
            window: Optional[int] = None, remat: bool = True,
            unroll: bool = False, slot_remat: bool = False,
            impl: Optional[str] = None):
    """tokens: (B, S_tok); prefix_emb: (B, P, prefix_dim) when cfg.prefix_tokens.

    Returns (logits (B, P+S_tok, V), aux dict of scalar reg losses).
    """
    x, aux = forward_hidden(params, cfg, tokens, prefix_emb, window=window,
                            remat=remat, unroll=unroll,
                            slot_remat=slot_remat, impl=impl)
    return _unembed(params, cfg, x), aux


def chunked_ce(x, head, labels, n_chunks: int = 16):
    """Cross-entropy WITHOUT materialising the (B, S, V) logits tensor.

    x: (B, S, d) final hidden states; head: (d, V); labels: (B, S).
    lax.scan over vocab chunks with a running (max, sumexp, label-logit)
    carry; the chunk body is checkpointed so backward recomputes the chunk
    logits instead of saving them.  Peak activation: (B, S, V/n_chunks).
    """
    B, S, d = x.shape
    V = head.shape[1]
    c = -(-V // n_chunks)
    pad = n_chunks * c - V
    headp = jnp.pad(head, ((0, 0), (0, pad)))
    chunks = headp.reshape(d, n_chunks, c).transpose(1, 0, 2)   # (n,d,c)
    xf = x.astype(jnp.float32)

    @jax.checkpoint
    def body(carry, inp):
        m, se, lab = carry
        w, idx = inp                                   # (d,c), chunk index
        lg = (xf @ w.astype(jnp.float32))              # (B,S,c)
        base = idx * c
        valid = base + jnp.arange(c) < V
        lg = jnp.where(valid[None, None, :], lg, -1e30)
        m_new = jnp.maximum(m, jnp.max(lg, axis=-1))
        se = se * jnp.exp(m - m_new) + jnp.sum(jnp.exp(lg - m_new[..., None]),
                                               axis=-1)
        local = labels - base
        inside = (local >= 0) & (local < c)
        picked = jnp.take_along_axis(lg, jnp.clip(local, 0, c - 1)[..., None],
                                     axis=-1)[..., 0]
        lab = jnp.where(inside, picked, lab)
        return (m_new, se, lab), None

    m0 = jnp.full((B, S), -1e30, jnp.float32)
    se0 = jnp.zeros((B, S), jnp.float32)
    lab0 = jnp.full((B, S), -1e30, jnp.float32)
    (m, se, lab), _ = jax.lax.scan(body, (m0, se0, lab0),
                                   (chunks, jnp.arange(n_chunks)))
    lse = m + jnp.log(se)
    return jnp.mean(lse - lab)


def loss_fn(params, cfg: ModelConfig, tokens, prefix_emb=None, *,
            window: Optional[int] = None, remat: bool = True,
            unroll: bool = False, ce_impl: str = "dense",
            slot_remat: bool = False, impl: Optional[str] = None):
    """Next-token cross-entropy (+ MoE aux).  Returns (loss, metrics).

    ce_impl='chunked' streams the vocab dimension (never materialises the
    (B, S, V) logits) — the beyond-paper memory optimisation from §Perf.
    ``impl`` selects the mixer kernel implementation (``kernels.ops``);
    every impl is differentiable (the attention/SSD kernels carry custom
    VJPs), so train steps pass the same impl they run forward.
    """
    P = cfg.prefix_tokens if cfg.prefix_tokens else 0
    if ce_impl == "chunked":
        x, aux = forward_hidden(params, cfg, tokens, prefix_emb,
                                window=window, remat=remat, unroll=unroll,
                                slot_remat=slot_remat, impl=impl)
        x = rms_norm(x, params["final_norm"], cfg.rms_eps)
        head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        if P:
            xs, labels = x[:, P - 1: -1], tokens
        else:
            xs, labels = x[:, :-1], tokens[:, 1:]
        ce = chunked_ce(xs, head, labels)
    else:
        logits, aux = forward(params, cfg, tokens, prefix_emb, window=window,
                              remat=remat, unroll=unroll,
                              slot_remat=slot_remat, impl=impl)
        if P:
            pred = logits[:, P - 1: -1]      # positions predicting tokens[0:]
            labels = tokens
        else:
            pred = logits[:, :-1]
            labels = tokens[:, 1:]
        logp = jax.nn.log_softmax(pred.astype(jnp.float32), axis=-1)
        ce = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        ce = jnp.mean(ce)
    total = ce + aux["load_balance"] + aux["router_z"]
    return total, {"ce": ce, **aux}


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode

def _slot_cache(cfg: ModelConfig, spec, batch: int, max_seq: int,
                window: Optional[int]):
    if spec.mixer == "attn":
        return attn_mod.init_kv_cache(cfg, batch, max_seq, window)
    return ssm_mod.init_mamba_cache(cfg, batch)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               window: Optional[int] = None) -> Params:
    """Stacked (n_periods leading axis) cache pytree."""
    if window is None:
        window = cfg.sliding_window
    one = {f"slot{i}": _slot_cache(cfg, spec, batch, max_seq, window)
           for i, spec in enumerate(cfg.period)}
    return jax.tree.map(
        lambda t: jnp.broadcast_to(t, (cfg.n_periods,) + t.shape).copy(), one)


def cache_logical_axes(cfg: ModelConfig) -> Params:
    one = {}
    for i, spec in enumerate(cfg.period):
        if spec.mixer == "attn":
            one[f"slot{i}"] = attn_mod.kv_cache_logical_axes()
        else:
            one[f"slot{i}"] = ssm_mod.mamba_cache_logical_axes()
    return jax.tree.map(
        lambda t: ("layers",) + t, one,
        is_leaf=lambda x: isinstance(x, tuple) and not isinstance(x, dict))


def prefill(params, cfg: ModelConfig, tokens, prefix_emb=None, *,
            max_seq: int, window: Optional[int] = None,
            unroll: bool = False):
    """Prompt ingestion.  Returns (last-token logits (B, V), cache)."""
    if window is None:
        window = cfg.sliding_window
    x = _embed(params, cfg, tokens, prefix_emb)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def period_body(carry, period_params):
        h = carry
        caches = {}
        for i, spec in enumerate(cfg.period):
            sp = period_params[f"slot{i}"]
            hin = rms_norm(h, sp["norm1"], cfg.rms_eps)
            if spec.mixer == "attn":
                cache = attn_mod.init_kv_cache(cfg, B, max_seq, window)
                out, cache = attn_mod.attention_prefill(sp["mixer"], cfg, hin,
                                                        cache, window=window)
            else:
                out, (conv, ssm_state) = ssm_mod.mamba_forward(
                    sp["mixer"], cfg, hin, return_state=True)
                cache = {"conv": conv, "ssm": ssm_state}
            h = h + out
            if spec.ffn != "none":
                hin = rms_norm(h, sp["norm2"], cfg.rms_eps)
                if spec.ffn == "mlp":
                    hin = apply_mlp(sp["ffn"], hin)
                else:
                    hin, _ = moe_mod.apply_moe(sp["ffn"], cfg, hin)
                h = h + hin
            h = constrain(h, "batch", "res_seq", "embed_act")
            caches[f"slot{i}"] = cache
        return h, caches

    if unroll:
        caches = []
        for idx in range(cfg.n_periods):
            pp = jax.tree.map(lambda t, idx=idx: t[idx], params["periods"])
            x, c = period_body(x, pp)
            caches.append(c)
        caches = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    else:
        x, caches = jax.lax.scan(period_body, x, params["periods"])
    logits = _unembed(params, cfg, x[:, -1:, :])[:, 0]
    return logits, caches


def decode_step(params, cfg: ModelConfig, token, cache, pos, *,
                window: Optional[int] = None, unroll: bool = False):
    """One decode step.  token: (B, 1) int32; pos: scalar int32, or a
    (B,) int32 vector of per-sequence positions (the serving arena path —
    see ``attention_decode``; SSM state is position-free either way).

    Returns (logits (B, V), new cache).
    """
    if window is None:
        window = cfg.sliding_window
    x = params["embed"].astype(cfg.cdtype())[token]       # (B,1,d)

    def period_body(carry, xs):
        h = carry
        period_params, cache_in = xs
        cache_out = {}
        for i, spec in enumerate(cfg.period):
            sp = period_params[f"slot{i}"]
            hin = rms_norm(h, sp["norm1"], cfg.rms_eps)
            if spec.mixer == "attn":
                out, c = attn_mod.attention_decode(sp["mixer"], cfg, hin,
                                                   cache_in[f"slot{i}"], pos,
                                                   window=window)
            else:
                out, c = ssm_mod.mamba_decode(sp["mixer"], cfg, hin,
                                              cache_in[f"slot{i}"])
            h = h + out
            if spec.ffn != "none":
                hin = rms_norm(h, sp["norm2"], cfg.rms_eps)
                if spec.ffn == "mlp":
                    hin = apply_mlp(sp["ffn"], hin)
                else:
                    hin, _ = moe_mod.apply_moe(sp["ffn"], cfg, hin)
                h = h + hin
            cache_out[f"slot{i}"] = c
        return h, cache_out

    if unroll:
        new_caches = []
        for idx in range(cfg.n_periods):
            sel = lambda t, idx=idx: t[idx]
            x, c = period_body(x, (jax.tree.map(sel, params["periods"]),
                                   jax.tree.map(sel, cache)))
            new_caches.append(c)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)
    else:
        x, new_cache = jax.lax.scan(period_body, x, (params["periods"], cache))
    logits = _unembed(params, cfg, x)[:, 0]
    return logits, new_cache
