"""Shared transformer building blocks: RMSNorm, RoPE, SwiGLU MLP, init."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding import constrain


# ---------------------------------------------------------------------------
# init helpers

def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init (LeCun-style)."""
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms

def rms_norm(x, weight, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def gated_rms_norm(x, gate, weight, eps: float = 1e-5):
    """Mamba2's norm(x * silu(z)) fused gate."""
    return rms_norm(x * jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype),
                    weight, eps)


# ---------------------------------------------------------------------------
# rotary embeddings

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP

def init_mlp(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype),
    }


def mlp_logical_axes():
    return {
        "w_gate": ("embed", "ff"),
        "w_up": ("embed", "ff"),
        "w_down": ("ff", "embed"),
    }


def apply_mlp(params, x):
    h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    h = constrain(h, "batch", "seq", "ff")
    return h @ params["w_down"]
