"""Capacity-based top-k mixture-of-experts FFN (expert-parallel friendly).

Routing uses the Switch/GShard dispatch-einsum formulation with a *group*
dimension: tokens are routed within groups of ``group_size`` so the dispatch
tensors stay small (dispatch FLOPs ~= top_k * group * cf * d per token,
~1% of expert FLOPs at group=256).  With the expert dim sharded over the
``model`` mesh axis the dispatch einsums lower to all-to-alls — the TPU
analogue of expert-parallel NCCL a2a.

Aux losses: Switch load-balance loss + router z-loss, returned for the train
loss to consume.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init
from repro.sharding import constrain

GROUP_SIZE = 256


def init_moe(key, cfg: ModelConfig):
    m = cfg.moe
    keys = jax.random.split(key, 5)
    d, de, E = cfg.d_model, m.d_expert, m.n_experts
    p = {
        "router": dense_init(keys[0], (d, E), jnp.float32, scale=d ** -0.5),
        "w_gate": dense_init(keys[1], (E, d, de), cfg.pdtype()),
        "w_up": dense_init(keys[2], (E, d, de), cfg.pdtype()),
        "w_down": dense_init(keys[3], (E, de, d), cfg.pdtype()),
    }
    if m.n_shared_experts:
        ds = m.n_shared_experts * de
        ks = jax.random.split(keys[4], 3)
        p["shared"] = {
            "w_gate": dense_init(ks[0], (d, ds), cfg.pdtype()),
            "w_up": dense_init(ks[1], (d, ds), cfg.pdtype()),
            "w_down": dense_init(ks[2], (ds, d), cfg.pdtype()),
        }
    return p


def moe_logical_axes(cfg: ModelConfig):
    ax = {
        "router": ("embed", None),
        "w_gate": ("expert", "embed", "ff"),
        "w_up": ("expert", "embed", "ff"),
        "w_down": ("expert", "ff", "embed"),
    }
    if cfg.moe.n_shared_experts:
        ax["shared"] = {"w_gate": ("embed", "ff"), "w_up": ("embed", "ff"),
                        "w_down": ("ff", "embed")}
    return ax


def apply_moe(params, cfg: ModelConfig, x) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, S, d) -> (y, aux).  aux: load_balance, router_z (scalars)."""
    m = cfg.moe
    B, S, d = x.shape
    E, k = m.n_experts, m.top_k
    G = min(GROUP_SIZE, S)
    assert S % G == 0, (S, G)
    ng = S // G
    xg = x.reshape(B * ng, G, d)
    N = B * ng

    logits = (xg.astype(jnp.float32) @ params["router"])          # (N,G,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)                      # (N,G,k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # capacity-based dispatch
    C = math.ceil(k * G * m.capacity_factor / E)
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)            # (N,G,k,E)
    flat = onehot.reshape(N, G * k, E)
    pos = jnp.cumsum(flat, axis=1) - 1.0                          # (N,G*k,E)
    pos = (pos * flat).reshape(N, G, k, E)
    keep = (pos < C).astype(jnp.float32) * onehot
    # dispatch (N,G,E,C): token -> (expert, slot)
    slot_oh = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=jnp.float32)
    dispatch = jnp.einsum("ngke,ngkec->ngec", keep, slot_oh)
    combine = jnp.einsum("ngke,ngkec->ngec", keep * gate_vals[..., None], slot_oh)

    xe = jnp.einsum("ngec,ngd->necd", dispatch, xg.astype(jnp.float32))
    # the group dim N = B*S/G MUST stay batch-sharded: leaving it
    # unconstrained made SPMD replicate expert compute across the data axis
    # (16x redundant FLOPs — dbrx useful_flop_ratio 0.11, see §Perf)
    xe = constrain(xe.astype(x.dtype), "batch", "expert", None, None)
    h = jax.nn.silu(jnp.einsum("necd,edf->necf", xe, params["w_gate"])) * \
        jnp.einsum("necd,edf->necf", xe, params["w_up"])
    h = constrain(h, "batch", "expert", None, "ff")
    ye = jnp.einsum("necf,efd->necd", h, params["w_down"])
    ye = constrain(ye, "batch", "expert", None, None)
    y = jnp.einsum("necd,ngec->ngd", ye.astype(jnp.float32), combine)
    y = y.reshape(B, S, d).astype(x.dtype)

    if m.n_shared_experts:
        sp = params["shared"]
        hs = jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])
        y = y + hs @ sp["w_down"]

    # aux losses (fp32 scalars)
    density = jnp.mean(onehot.sum(axis=2), axis=(0, 1))           # f_e
    mean_prob = jnp.mean(probs, axis=(0, 1))                      # P_e
    load_balance = E * jnp.sum(density * mean_prob) * m.aux_coef
    router_z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * m.router_z_coef
    return y, {"load_balance": load_balance, "router_z": router_z}
