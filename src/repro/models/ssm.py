"""Mamba2 block (SSD) — train/prefill forward and single-step decode.

Block layout follows the Mamba2 paper: fused in_proj -> (z, xBC, dt),
causal depthwise conv over xBC, SiLU, SSD scan over heads, D skip,
gated RMSNorm, out_proj.  Decode carries (conv_state, ssm_state) —
constant-size state, which is why SSM archs run long_500k natively.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models.layers import dense_init, gated_rms_norm
from repro.sharding import constrain


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    conv_ch = di + 2 * s.n_groups * s.d_state
    return s, di, nh, conv_ch


def init_mamba(key, cfg: ModelConfig):
    s, di, nh, conv_ch = _dims(cfg)
    keys = jax.random.split(key, 4)
    d_in_proj = 2 * di + 2 * s.n_groups * s.d_state + nh
    dt = jnp.exp(jax.random.uniform(keys[2], (nh,)) *
                 (jnp.log(s.dt_max) - jnp.log(s.dt_min)) + jnp.log(s.dt_min))
    # store softplus^-1(dt)
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    return {
        "in_proj": dense_init(keys[0], (cfg.d_model, d_in_proj), cfg.pdtype()),
        "conv_w": dense_init(keys[1], (s.d_conv, conv_ch), cfg.pdtype(),
                             scale=s.d_conv ** -0.5),
        "conv_b": jnp.zeros((conv_ch,), cfg.pdtype()),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm": jnp.zeros((di,), cfg.pdtype()),
        "out_proj": dense_init(keys[3], (di, cfg.d_model), cfg.pdtype()),
    }


def mamba_logical_axes(cfg: ModelConfig):
    return {
        "in_proj": ("embed", "ff"),
        "conv_w": ("conv", None),
        "conv_b": (None,),
        "A_log": (None,),
        "D": (None,),
        "dt_bias": (None,),
        "norm": ("ff",),
        "out_proj": ("ff", "embed"),
    }


def _split_proj(cfg, zxbcdt):
    s, di, nh, _ = _dims(cfg)
    gn = s.n_groups * s.d_state
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di: 2 * di + 2 * gn]
    dt = zxbcdt[..., 2 * di + 2 * gn:]
    return z, xBC, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv.  xBC: (B, S, C); w: (K, C)."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + xBC.shape[1], :] * w[i] for i in range(K))
    return out + b


def mamba_forward(params, cfg: ModelConfig, u, return_state: bool = False,
                  impl=None):
    """u: (B, S, d) -> y (B, S, d) [, (conv_state, ssm_state)].

    ``impl`` selects the SSD kernel implementation (see ``kernels.ops``);
    None defers to the ambient default.  Every impl is differentiable (the
    Pallas SSD kernel carries a custom VJP), so training steps thread the
    SAME impl they run forward.
    """
    s, di, nh, conv_ch = _dims(cfg)
    B, S, _ = u.shape
    zxbcdt = u @ params["in_proj"]
    zxbcdt = constrain(zxbcdt, "batch", "seq", "ff")
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    xBC_conv = _causal_conv(xBC, params["conv_w"], params["conv_b"])
    xBC_act = jax.nn.silu(xBC_conv)
    gn = s.n_groups * s.d_state
    # explicit re-shard of the slices: x stays head-sharded; the small B/C
    # group projections replicate (they feed every head) — without these
    # constraints SPMD all-gathers the whole ff-sharded xBC per layer
    x = xBC_act[..., :di].reshape(B, S, nh, s.head_dim)
    Bm = xBC_act[..., di: di + gn].reshape(B, S, s.n_groups, s.d_state)
    Cm = xBC_act[..., di + gn:].reshape(B, S, s.n_groups, s.d_state)
    Bm = constrain(Bm, "batch", "seq", None, None)
    Cm = constrain(Cm, "batch", "seq", None, None)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    x = constrain(x, "batch", "seq", "heads", None)
    y, state = ops.ssd(x, dt, A, Bm, Cm, chunk=s.chunk, impl=impl)
    y = y + params["D"].astype(y.dtype)[None, None, :, None] * x
    y = y.reshape(B, S, di)
    y = gated_rms_norm(y, z, params["norm"], cfg.rms_eps)
    out = y @ params["out_proj"]
    if not return_state:
        return out
    conv_state = xBC[:, S - (s.d_conv - 1):, :] if S >= s.d_conv - 1 else \
        jnp.pad(xBC, ((0, 0), (s.d_conv - 1 - S, 0), (0, 0)))
    return out, (conv_state.astype(cfg.cdtype()), state)


# ---------------------------------------------------------------------------
# decode

def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=None):
    s, di, nh, conv_ch = _dims(cfg)
    dtype = dtype or cfg.cdtype()
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
    }


def mamba_cache_logical_axes():
    return {"conv": ("batch", None, "ff"),
            "ssm": ("batch", "heads", None, "state")}


def mamba_decode(params, cfg: ModelConfig, u, cache) -> Tuple[jax.Array, dict]:
    """One token: u (B, 1, d) -> (y (B, 1, d), cache)."""
    s, di, nh, conv_ch = _dims(cfg)
    B = u.shape[0]
    zxbcdt = u @ params["in_proj"]
    z, xBC, dt = _split_proj(cfg, zxbcdt)                 # (B,1,*)
    window = jnp.concatenate([cache["conv"].astype(xBC.dtype), xBC], axis=1)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          params["conv_w"].astype(jnp.float32)) + params["conv_b"].astype(jnp.float32)
    xBC_act = jax.nn.silu(conv_out)[:, None, :].astype(u.dtype)  # (B,1,C)
    gn = s.n_groups * s.d_state
    x = xBC_act[..., :di].reshape(B, nh, s.head_dim)
    Bm = xBC_act[..., di: di + gn].reshape(B, s.n_groups, s.d_state)
    Cm = xBC_act[..., di + gn:].reshape(B, s.n_groups, s.d_state)
    rep = nh // s.n_groups
    Bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)  # (B,nh,N)
    Ch = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,nh)
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dtv * A)                              # (B,nh)
    xf = x.astype(jnp.float32)
    ssm = cache["ssm"] * decay[:, :, None, None] + \
        jnp.einsum("bhn,bhp,bh->bhpn", Bh, xf, dtv)
    y = jnp.einsum("bhn,bhpn->bhp", Ch, ssm) + params["D"][None, :, None] * xf
    y = y.reshape(B, 1, di).astype(u.dtype)
    y = gated_rms_norm(y, z, params["norm"], cfg.rms_eps)
    out = y @ params["out_proj"]
    new_cache = {"conv": window[:, 1:, :].astype(cache["conv"].dtype),
                 "ssm": ssm}
    return out, new_cache
