"""VisionNet — the paper's CNN (Fig. 2), in pure JAX.

Three 3x3 conv layers (first two followed by 2x2 max-pool), dropout,
dense-64, dropout, single sigmoid output (binary face-mask head).  The
paper's asynchronous-FL baseline needs a shallow/deep split: conv stack =
"shallow", dense head = "deep" (matching [4]'s layerwise schedule).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.visionnet import VisionNetConfig


def init_visionnet(key, cfg: VisionNetConfig) -> Dict:
    keys = jax.random.split(key, len(cfg.conv_features) + 2)
    params: Dict = {"conv": []}
    c_in = cfg.channels
    size = cfg.image_size
    for i, c_out in enumerate(cfg.conv_features):
        fan_in = cfg.kernel_size * cfg.kernel_size * c_in
        w = jax.random.truncated_normal(
            keys[i], -2, 2, (cfg.kernel_size, cfg.kernel_size, c_in, c_out)
        ) * (2.0 / fan_in) ** 0.5
        params["conv"].append({"w": w.astype(jnp.float32),
                               "b": jnp.zeros((c_out,), jnp.float32)})
        c_in = c_out
        if i < 2:                                    # first two convs pooled
            size //= 2
    flat = size * size * c_in
    params["dense"] = {
        "w": (jax.random.truncated_normal(keys[-2], -2, 2,
                                          (flat, cfg.dense_features))
              * (2.0 / flat) ** 0.5).astype(jnp.float32),
        "b": jnp.zeros((cfg.dense_features,), jnp.float32),
    }
    params["head"] = {
        "w": (jax.random.truncated_normal(keys[-1], -2, 2,
                                          (cfg.dense_features, cfg.n_classes))
              * (1.0 / cfg.dense_features) ** 0.5).astype(jnp.float32),
        "b": jnp.zeros((cfg.n_classes,), jnp.float32),
    }
    return params


def shallow_deep_split(params: Dict):
    """Param-path masks for the async-FL baseline: conv = shallow, rest = deep."""
    shallow = jax.tree.map(lambda _: False, params)
    shallow["conv"] = jax.tree.map(lambda _: True, params["conv"])
    return shallow


def _conv2d(x, w, b):
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out + b


def _max_pool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def visionnet_forward(params: Dict, cfg: VisionNetConfig, images,
                      *, train: bool = False,
                      dropout_key: Optional[jax.Array] = None):
    """images: (B, H, W, C) in [0, 1].  Returns sigmoid-prob (B,) fp32."""
    x = images.astype(jnp.float32)
    for i, cp in enumerate(params["conv"]):
        x = jax.nn.relu(_conv2d(x, cp["w"], cp["b"]))
        if i < 2:
            x = _max_pool(x)
    x = x.reshape(x.shape[0], -1)
    if train and dropout_key is not None:
        k1, k2 = jax.random.split(dropout_key)
        keep = 1.0 - cfg.dropout_rate
        x = x * jax.random.bernoulli(k1, keep, x.shape) / keep
    x = jax.nn.relu(x @ params["dense"]["w"] + params["dense"]["b"])
    if train and dropout_key is not None:
        x = x * jax.random.bernoulli(k2, keep, x.shape) / keep
    logits = x @ params["head"]["w"] + params["head"]["b"]
    return jax.nn.sigmoid(logits[:, 0])


def bce_loss(probs, labels, eps: float = 1e-7):
    """Binary cross-entropy on sigmoid outputs (paper's Model_loss)."""
    p = jnp.clip(probs, eps, 1 - eps)
    y = labels.astype(jnp.float32)
    return -jnp.mean(y * jnp.log(p) + (1 - y) * jnp.log(1 - p))
