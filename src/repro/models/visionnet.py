"""VisionNet — the paper's CNN (Fig. 2), in pure JAX.

Three 3x3 conv layers (first two followed by 2x2 max-pool), dropout,
dense-64, dropout, single sigmoid output (binary face-mask head).  The
paper's asynchronous-FL baseline needs a shallow/deep split: conv stack =
"shallow", dense head = "deep" (matching [4]'s layerwise schedule).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.visionnet import VisionNetConfig


def init_visionnet(key, cfg: VisionNetConfig) -> Dict:
    keys = jax.random.split(key, len(cfg.conv_features) + 2)
    params: Dict = {"conv": []}
    c_in = cfg.channels
    size = cfg.image_size
    for i, c_out in enumerate(cfg.conv_features):
        fan_in = cfg.kernel_size * cfg.kernel_size * c_in
        w = jax.random.truncated_normal(
            keys[i], -2, 2, (cfg.kernel_size, cfg.kernel_size, c_in, c_out)
        ) * (2.0 / fan_in) ** 0.5
        params["conv"].append({"w": w.astype(jnp.float32),
                               "b": jnp.zeros((c_out,), jnp.float32)})
        c_in = c_out
        if i < 2:                                    # first two convs pooled
            size //= 2
    flat = size * size * c_in
    params["dense"] = {
        "w": (jax.random.truncated_normal(keys[-2], -2, 2,
                                          (flat, cfg.dense_features))
              * (2.0 / flat) ** 0.5).astype(jnp.float32),
        "b": jnp.zeros((cfg.dense_features,), jnp.float32),
    }
    params["head"] = {
        "w": (jax.random.truncated_normal(keys[-1], -2, 2,
                                          (cfg.dense_features, cfg.n_classes))
              * (1.0 / cfg.dense_features) ** 0.5).astype(jnp.float32),
        "b": jnp.zeros((cfg.n_classes,), jnp.float32),
    }
    return params


def shallow_deep_split(params: Dict):
    """Param-path masks for the async-FL baseline: conv = shallow, rest = deep."""
    shallow = jax.tree.map(lambda _: False, params)
    shallow["conv"] = jax.tree.map(lambda _: True, params["conv"])
    return shallow


_DN = ("NHWC", "HWIO", "NHWC")


def _conv_raw(x, w):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME", dimension_numbers=_DN)


def _conv2d(x, w, b):
    return _conv_raw(x, w) + b


@jax.custom_vjp
def _conv2d_fused(x, w, b):
    """Same conv, vmap-friendly gradient.

    vmapping the stock conv over per-client weights makes XLA's autodiff
    emit grouped-conv gradient kernels that fall off the fast path on CPU
    (measured 8x slower than K separate convs).  This VJP keeps both
    backward operands on fast paths: dx is a forward-style conv with the
    spatially-flipped, in/out-swapped kernel (grouped conv FORWARD is
    fine), and dw is an im2col matmul, which vmap turns into a batched
    GEMM.  Assumes odd kernel, stride 1, SAME — the VisionNet setting.
    """
    return _conv2d(x, w, b)


def _conv2d_fused_fwd(x, w, b):
    return _conv2d(x, w, b), (x, w)


def _shift_patches(x, k):
    """(B,H,W,C) -> (B,H,W,k,k,C) SAME patches via pad + k² slices — pure
    data movement (conv_general_dilated_patches lowers to a grouped conv,
    which is the slow path this VJP exists to avoid)."""
    pad = k // 2
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    H, W = x.shape[1], x.shape[2]
    rows = [jnp.stack([xp[:, i:i + H, j:j + W, :] for j in range(k)], axis=3)
            for i in range(k)]
    return jnp.stack(rows, axis=3)


def _conv2d_fused_bwd(res, g):
    x, w = res
    kh, _, _, _ = w.shape
    w_t = jnp.flip(w, (0, 1)).transpose(0, 1, 3, 2)         # (kh,kw,cout,cin)
    dx = jax.lax.conv_general_dilated(
        g, w_t, window_strides=(1, 1), padding="SAME", dimension_numbers=_DN)
    dw = jnp.einsum("bhwijc,bhwo->ijco", _shift_patches(x, kh), g)
    return dx, dw, jnp.sum(g, (0, 1, 2))


_conv2d_fused.defvjp(_conv2d_fused_fwd, _conv2d_fused_bwd)

_CONV_IMPLS = {"native": _conv2d, "fused": _conv2d_fused}


def _max_pool(x):
    """2x2/stride-2 max-pool via reshape (== VALID reduce_window, but its
    backward is a cheap argmax-where instead of XLA's select-and-scatter,
    which is very slow on CPU)."""
    b, h, w, c = x.shape
    x = x[:, : h - h % 2, : w - w % 2, :]
    return x.reshape(b, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))


def visionnet_forward(params: Dict, cfg: VisionNetConfig, images,
                      *, train: bool = False,
                      dropout_key: Optional[jax.Array] = None,
                      conv_impl: str = "native"):
    """images: (B, H, W, C) in [0, 1].  Returns sigmoid-prob (B,) fp32.

    ``conv_impl``: 'native' (stock conv) or 'fused' (custom-VJP conv whose
    backward stays fast when the forward is vmapped over per-client
    weights — the stacked round engine's setting).
    """
    conv = _CONV_IMPLS[conv_impl]
    x = images.astype(jnp.float32)
    for i, cp in enumerate(params["conv"]):
        x = jax.nn.relu(conv(x, cp["w"], cp["b"]))
        if i < 2:
            x = _max_pool(x)
    x = x.reshape(x.shape[0], -1)
    if train and dropout_key is not None:
        k1, k2 = jax.random.split(dropout_key)
        keep = 1.0 - cfg.dropout_rate
        x = x * jax.random.bernoulli(k1, keep, x.shape) / keep
    x = jax.nn.relu(x @ params["dense"]["w"] + params["dense"]["b"])
    if train and dropout_key is not None:
        x = x * jax.random.bernoulli(k2, keep, x.shape) / keep
    logits = x @ params["head"]["w"] + params["head"]["b"]
    return jax.nn.sigmoid(logits[:, 0])


def bce_loss(probs, labels, eps: float = 1e-7):
    """Binary cross-entropy on sigmoid outputs (paper's Model_loss)."""
    p = jnp.clip(probs, eps, 1 - eps)
    y = labels.astype(jnp.float32)
    return -jnp.mean(y * jnp.log(p) + (1 - y) * jnp.log(1 - p))
