"""Blockwise (flash) causal attention Pallas kernel, TPU-targeted.

Layout: q (B, Hq, S, hd), k/v (B, Hkv, T, hd) — head-major so the last two
dims are the MXU matmul operands.  Grid (B, Hq, S/bq, T/bk) with the KV block
index innermost and sequential; running max / denominator / accumulator live
in VMEM scratch and persist across KV iterations (the standard TPU flash
pattern).  GQA is handled in the k/v index_map (query head h reads KV head
h // group) so KV is never materialised per-query-head.

Causal + sliding-window masking is done blockwise: fully-masked KV blocks are
skipped with pl.when, diagonal blocks masked via iota.

DIFFERENTIABLE: the forward additionally emits the per-row logsumexp, and
``flash_attention`` carries a ``jax.custom_vjp`` whose backward recomputes
the blockwise softmax from the saved (q, k, v, out, lse) residuals and
streams dq/dk/dv over KV blocks (``_streaming_attn_bwd``) — the same
recompute-not-materialise pattern as ``kernels.kl_mutual`` /
``kernels.sparse_kl``, so the O(S·T) score matrix never hits HBM in either
direction.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref, acc_ref,
                 *, bq: int, bk: int, n_kv_blocks: int, causal: bool,
                 window: Optional[int], sm_scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * bq
    k_start = ik * bk

    # Block-level reachability: any (qpos, kpos) pair with kpos <= qpos and
    # qpos - kpos < window?  Max qpos in block = q_start+bq-1; min kpos = k_start.
    live = True
    if causal:
        live = k_start <= q_start + bq - 1
    if window is not None:
        live = jnp.logical_and(live, (q_start) - (k_start + bk - 1) < window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)              # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * sm_scale

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                              # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        scale = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                           # (bq, bk)
        l_ref[...] = l_ref[...] * scale + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * scale + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))

    @pl.when(ik == n_kv_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)
        # per-row logsumexp Z = m + log(l): the backward's softmax residual
        lse_ref[0, 0] = m_ref[...] + jnp.log(denom)


def _flash_forward(q, k, v, causal: bool, window: Optional[int],
                   block_q: int, block_k: int, interpret: bool):
    """One pallas_call -> (out (B, Hq, S, hd), lse (B, Hq, S) fp32)."""
    B, Hq, S, hd = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    group = Hq // Hkv
    bq = min(block_q, S)
    bk = min(block_k, T)
    pad_q = (-S) % bq
    pad_k = (-T) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        # padded KV positions are masked out via causal (kpos > qpos) only if
        # they trail every query; with padding at the end this holds for
        # causal attention, which is the only mode the kernel serves.
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sq, Tk = S + pad_q, T + pad_k
    n_q, n_k = Sq // bq, Tk // bk

    kernel = functools.partial(
        _attn_kernel, bq=bq, bk=bk, n_kv_blocks=n_k, causal=causal,
        window=window, sm_scale=hd ** -0.5)

    out, lse = pl.pallas_call(
        kernel,
        grid=(B, Hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq, 1), lambda b, h, i, j: (b, h, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hq, Sq, hd), q.dtype),
            jax.ShapeDtypeStruct((B, Hq, Sq, 1), jnp.float32),
        ],
        scratch_shapes=[
            # running max, denominator, output accumulator (fp32, VMEM)
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S], lse[:, :, :S, 0]


def _streaming_attn_bwd(q, k, v, out, lse, dout, causal: bool,
                        window: Optional[int], block_k: int):
    """Flash backward, streamed over KV blocks in plain JAX (lax.scan).

    Recomputes each (S, bk) score block from the saved row logsumexp
    instead of materialising the O(S·T) probability matrix:

        delta = sum_d dout * out                         (per row)
        p     = exp(s_masked - lse)
        dv_j  = p^T . dout ;  dp = dout . v_j^T
        ds    = p * (dp - delta) * sm_scale
        dq   += ds . k_j ;  dk_j = ds^T . q

    GQA folds the query-group axis into the einsums (dk/dv sum over the
    group); masked entries have s = NEG_INF so p underflows to exactly 0.
    """
    B, Hq, S, hd = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    G = Hq // Hkv
    sm_scale = hd ** -0.5
    qf = q.reshape(B, Hkv, G, S, hd).astype(jnp.float32)
    doutf = dout.reshape(B, Hkv, G, S, hd).astype(jnp.float32)
    outf = out.reshape(B, Hkv, G, S, hd).astype(jnp.float32)
    lsef = lse.reshape(B, Hkv, G, S)
    delta = jnp.sum(doutf * outf, axis=-1)               # (B,Hkv,G,S)

    bk = min(block_k, T)
    pad = (-T) % bk
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if pad:
        kf = jnp.pad(kf, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, 0), (0, pad), (0, 0)))
    n_k = (T + pad) // bk
    kb = jnp.moveaxis(kf.reshape(B, Hkv, n_k, bk, hd), 2, 0)  # (nk,B,Hkv,bk,hd)
    vb = jnp.moveaxis(vf.reshape(B, Hkv, n_k, bk, hd), 2, 0)
    qpos = jnp.arange(S)

    def step(dq, xs):
        kblk, vblk, j = xs
        s = jnp.einsum("bkgsh,bkth->bkgst", qf, kblk) * sm_scale
        kpos = j * bk + jnp.arange(bk)
        mask = kpos[None, :] < T                         # k-padding
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= qpos[:, None] - kpos[None, :] < window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jnp.exp(s - lsef[..., None])                 # (B,Hkv,G,S,bk)
        dv = jnp.einsum("bkgst,bkgsh->bkth", p, doutf)
        dp = jnp.einsum("bkgsh,bkth->bkgst", doutf, vblk)
        ds = p * (dp - delta[..., None]) * sm_scale
        dq = dq + jnp.einsum("bkgst,bkth->bkgsh", ds, kblk)
        dk = jnp.einsum("bkgst,bkgsh->bkth", ds, qf)
        return dq, (dk, dv)

    dq0 = jnp.zeros((B, Hkv, G, S, hd), jnp.float32)
    dq, (dk, dv) = jax.lax.scan(step, dq0, (kb, vb, jnp.arange(n_k)))
    dq = dq.reshape(B, Hq, S, hd).astype(q.dtype)
    dk = jnp.moveaxis(dk, 0, 2).reshape(B, Hkv, T + pad, hd)[:, :, :T]
    dv = jnp.moveaxis(dv, 0, 2).reshape(B, Hkv, T + pad, hd)[:, :, :T]
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, causal, window, block_q, block_k, interpret):
    out, _ = _flash_forward(q, k, v, causal, window, block_q, block_k,
                            interpret)
    return out


def _flash_fwd(q, k, v, causal, window, block_q, block_k, interpret):
    out, lse = _flash_forward(q, k, v, causal, window, block_q, block_k,
                              interpret)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, block_q, block_k, interpret, res, dout):
    q, k, v, out, lse = res
    return _streaming_attn_bwd(q, k, v, out, lse, dout, causal, window,
                               block_k)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (B, Hq, S, hd); k, v: (B, Hkv, T, hd).  Returns (B, Hq, S, hd).

    Differentiable: carries a ``jax.custom_vjp`` (streamed recompute
    backward, ``_streaming_attn_bwd``) so training steps run the Pallas
    forward unmodified.
    """
    return _flash(q, k, v, bool(causal),
                  None if window is None else int(window),
                  int(block_q), int(block_k), bool(interpret))
