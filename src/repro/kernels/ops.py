"""jit-friendly kernel entry points with a runtime impl switch.

impl values:
  - "ref":       pure-jnp oracle (XLA-native; used by the dry-run so roofline
                 numbers reflect the compiler's own schedule)
  - "interpret": Pallas kernel body interpreted on CPU (correctness tests)
  - "pallas":    compiled Pallas TPU kernel (the production target)

Default comes from REPRO_KERNEL_IMPL or "ref"; tests/tools may override
per-scope with ``use_impl("interpret")``.

Production call sites do NOT rely on this ambient state: populations resolve
an impl once at construction (``resolve_impl``) and thread it through the
step factories as a plain argument.  ``use_impl`` exists for tests and the
dry-run only — the old thread-local version leaked inside jitted traces
(``lax.map`` chunking dispatches the body on worker threads that never saw
the override and silently fell back to the env default), so the override is
now a module-global set/restored by the context manager.
"""
from __future__ import annotations

import contextlib
import os
from typing import Optional

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.kl_mutual import kl_mutual as _kl_mutual_pallas
from repro.kernels.kl_mutual import kl_mutual_pair as _kl_mutual_pair
from repro.kernels.sparse_kl import sparse_kl_topk as _sparse_kl_pallas
from repro.kernels.ssd_scan import ssd_scan as _ssd_pallas

IMPLS = ("ref", "interpret", "pallas", "xla_flash")

_override: Optional[str] = None


def _check_impl(impl: str) -> str:
    """Every ops.* entry point funnels through here: an impl string that is
    not in ``IMPLS`` is a config bug, never a silent fallback."""
    if impl not in IMPLS:
        raise ValueError(f"unknown kernel impl {impl!r}; expected one "
                         f"of {IMPLS}")
    return impl


def get_impl() -> str:
    return _override or os.environ.get("REPRO_KERNEL_IMPL", "ref")


def set_impl(impl: str) -> None:
    global _override
    _check_impl(impl)
    _override = impl


@contextlib.contextmanager
def use_impl(impl: str):
    """Scoped ambient override — TESTS AND TOOLING ONLY (see module doc)."""
    global _override
    old = _override
    set_impl(impl)
    try:
        yield
    finally:
        _override = old


def resolve_impl(impl: Optional[str] = None) -> str:
    """Resolve the kernel-impl policy ONCE, at construction time.

    Priority: explicit value > REPRO_KERNEL_IMPL env > backend default —
    ``pallas`` when running on TPU, ``ref`` (the XLA-native oracle graph)
    everywhere else.  ``None``/"auto" defers to env/backend.  The resolved
    string is what populations bake into their jit caches and pass down the
    step factories, so the hot path never reads ambient state.
    """
    if impl and impl != "auto":
        return _check_impl(impl)
    env = os.environ.get("REPRO_KERNEL_IMPL")
    if env:
        return _check_impl(env)
    import jax
    return "pallas" if jax.default_backend() == "tpu" else "ref"


# ---------------------------------------------------------------------------

def attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
              positions_q=None, positions_k=None, impl: Optional[str] = None):
    """(B, S, H, hd)-layout attention dispatching to flash kernel or oracle.

    Explicit positions (the decode/cache path) always use the oracle — the
    flash kernel serves the self-attention train/prefill hot path.
    DIFFERENTIABLE on every impl: the flash kernel carries a custom VJP
    (streamed recompute backward), so training steps run the same impl
    forward and backward — there is no grad-time downgrade.
    """
    impl = _check_impl(impl or get_impl())
    if positions_q is not None or positions_k is not None:
        # decode/cache path: explicit positions -> oracle
        return ref.attention(q, k, v, causal=causal, window=window,
                             positions_q=positions_q, positions_k=positions_k)
    if impl == "ref":
        return ref.attention(q, k, v, causal=causal, window=window)
    if impl == "xla_flash":
        return ref.attention_xla_flash(q, k, v, causal=causal, window=window)
    qt, kt, vt = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
    out = flash_attention(qt, kt, vt, causal=causal, window=window,
                          interpret=(impl == "interpret"))
    return out.transpose(0, 2, 1, 3)


def mutual_kl(logits, *, temperature: float = 1.0, impl: Optional[str] = None):
    """(K, B, V) -> (K, B) average pairwise KL (paper Eq. 2)."""
    impl = _check_impl(impl or get_impl())
    if impl == "ref":
        return ref.mutual_kl(logits, temperature=temperature)
    return _kl_mutual_pallas(logits, temperature=temperature,
                             interpret=(impl == "interpret"))


def mutual_kl_pair(live, fixed, pair_w, *, temperature: float = 1.0,
                   impl: Optional[str] = None):
    """Pair-weighted rectangular Eq. 2: (Kl, B, V) live x (Kg, B, V) fixed
    with (Kl, Kg) weights -> (Kl, B).  DIFFERENTIABLE: kernel impls carry
    a custom VJP whose backward streams over vocab blocks; 'ref' is the
    plain-JAX oracle graph (AD-derived gradients).  The Eq.-2 training
    hot path — ``core.mutual.mutual_kl_terms`` routes here."""
    impl = _check_impl(impl or get_impl())
    if impl == "ref":
        return ref.mutual_kl_pair(live, fixed, pair_w,
                                  temperature=temperature)
    return _kl_mutual_pair(live, fixed, pair_w, temperature=temperature,
                           interpret=(impl == "interpret"))


def sparse_mutual_kl(live, idx, logp_top, pair_w, *,
                     temperature: float = 1.0, impl: Optional[str] = None):
    """Pair-weighted Eq. 2 against RECEIVED sparse (top-k) predictions.

    live (Kl, B, V) x idx/logp_top (J, B, k) with (Kl, J) weights ->
    (Kl, B).  DIFFERENTIABLE on the live side: kernel impls fuse the top-k
    gather with a streaming softmax/entropy pass (``kernels.sparse_kl``)
    and carry a custom VJP whose backward streams over vocab blocks; 'ref'
    is the plain-JAX oracle graph (AD-derived gradients).  The SparseDML
    combine hot path — ``core.mutual.sparse_mutual_kl_loss`` and
    ``core.mutual.sparse_kl_to_received`` route here."""
    impl = _check_impl(impl or get_impl())
    if impl == "ref":
        return ref.sparse_kl_pair(live, idx, logp_top, pair_w,
                                  temperature=temperature)
    return _sparse_kl_pallas(live, idx, logp_top, pair_w,
                             temperature=temperature,
                             interpret=(impl == "interpret"))


def ssd(x, dt, A, B_mat, C_mat, *, chunk: int = 256, initial_state=None,
        impl: Optional[str] = None):
    """Mamba2 SSD scan -> (y, final_state).

    DIFFERENTIABLE on every impl: the Pallas kernel carries a custom VJP
    (chunked reverse-scan backward).  ``initial_state`` continuation (the
    decode/cache path) always uses the oracle.
    """
    impl = _check_impl(impl or get_impl())
    # "xla_flash" is an attention-only variant; SSD has no XLA-flash
    # formulation, so that (VALID, documented) policy runs the oracle here
    if impl in ("ref", "xla_flash") or initial_state is not None:
        return ref.ssd(x, dt, A, B_mat, C_mat, chunk=chunk,
                       initial_state=initial_state)
    return _ssd_pallas(x, dt, A, B_mat, C_mat, chunk=chunk,
                       interpret=(impl == "interpret"))
