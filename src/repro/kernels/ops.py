"""jit-friendly kernel entry points with a runtime impl switch.

impl values:
  - "ref":       pure-jnp oracle (XLA-native; used by the dry-run so roofline
                 numbers reflect the compiler's own schedule)
  - "interpret": Pallas kernel body interpreted on CPU (correctness tests)
  - "pallas":    compiled Pallas TPU kernel (the production target)

Default comes from REPRO_KERNEL_IMPL or "ref"; override per-scope with
``use_impl("interpret")``.
"""
from __future__ import annotations

import contextlib
import os
import threading
from typing import Optional

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.kl_mutual import kl_mutual as _kl_mutual_pallas
from repro.kernels.kl_mutual import kl_mutual_pair as _kl_mutual_pair
from repro.kernels.ssd_scan import ssd_scan as _ssd_pallas

_local = threading.local()


def get_impl() -> str:
    return getattr(_local, "impl", os.environ.get("REPRO_KERNEL_IMPL", "ref"))


def set_impl(impl: str) -> None:
    assert impl in ("ref", "interpret", "pallas", "xla_flash"), impl
    _local.impl = impl


@contextlib.contextmanager
def use_impl(impl: str):
    old = get_impl()
    set_impl(impl)
    try:
        yield
    finally:
        set_impl(old)


# ---------------------------------------------------------------------------

def attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
              positions_q=None, positions_k=None, impl: Optional[str] = None):
    """(B, S, H, hd)-layout attention dispatching to flash kernel or oracle.

    Explicit positions (the decode/cache path) always use the oracle — the
    flash kernel serves the self-attention train/prefill hot path.
    """
    impl = impl or get_impl()
    if positions_q is not None or positions_k is not None:
        # decode/cache path: explicit positions -> oracle
        return ref.attention(q, k, v, causal=causal, window=window,
                             positions_q=positions_q, positions_k=positions_k)
    if impl == "ref":
        return ref.attention(q, k, v, causal=causal, window=window)
    if impl == "xla_flash":
        return ref.attention_xla_flash(q, k, v, causal=causal, window=window)
    qt, kt, vt = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
    out = flash_attention(qt, kt, vt, causal=causal, window=window,
                          interpret=(impl == "interpret"))
    return out.transpose(0, 2, 1, 3)


def mutual_kl(logits, *, temperature: float = 1.0, impl: Optional[str] = None):
    """(K, B, V) -> (K, B) average pairwise KL (paper Eq. 2)."""
    impl = impl or get_impl()
    if impl == "ref":
        return ref.mutual_kl(logits, temperature=temperature)
    return _kl_mutual_pallas(logits, temperature=temperature,
                             interpret=(impl == "interpret"))


def mutual_kl_pair(live, fixed, pair_w, *, temperature: float = 1.0,
                   impl: Optional[str] = None):
    """Pair-weighted rectangular Eq. 2: (Kl, B, V) live x (Kg, B, V) fixed
    with (Kl, Kg) weights -> (Kl, B).  DIFFERENTIABLE: kernel impls carry
    a custom VJP whose backward streams over vocab blocks; 'ref' is the
    plain-JAX oracle graph (AD-derived gradients).  The Eq.-2 training
    hot path — ``core.mutual.mutual_kl_terms`` routes here."""
    impl = impl or get_impl()
    if impl == "ref":
        return ref.mutual_kl_pair(live, fixed, pair_w,
                                  temperature=temperature)
    return _kl_mutual_pair(live, fixed, pair_w, temperature=temperature,
                           interpret=(impl == "interpret"))


def ssd(x, dt, A, B_mat, C_mat, *, chunk: int = 256, initial_state=None,
        impl: Optional[str] = None):
    """Mamba2 SSD scan -> (y, final_state)."""
    impl = impl or get_impl()
    if impl == "ref" or initial_state is not None:
        return ref.ssd(x, dt, A, B_mat, C_mat, chunk=chunk,
                       initial_state=initial_state)
    return _ssd_pallas(x, dt, A, B_mat, C_mat, chunk=chunk,
                       interpret=(impl == "interpret"))
