"""Pallas TPU kernels for the perf-critical hot spots, with jnp oracles.

- kl_mutual:        fused mutual-learning KL (paper Eq. 2) over the vocab
- flash_attention:  blockwise causal/sliding-window GQA attention
- ssd_scan:         Mamba2 SSD chunked scan with VMEM-resident state

``repro.kernels.ops`` is the public entry point (impl switch: ref /
interpret / pallas); ``repro.kernels.ref`` holds the oracles.
"""
from repro.kernels import ops, ref  # noqa: F401
