"""Fused mutual-learning KL Pallas kernel (paper Eq. 2 at vocab scale).

Computes, for client-stacked logits (K, B, V):

    out[i, b] = 1/(K-1) * sum_{j != i} KL(P_i(b) || P_j(b))

in ONE streaming pass over the vocabulary — no K softmax tensors ever hit
HBM.  Uses a flash-style online decomposition:

    KL(P_i || P_j) = (Z_j - Z_i) + (1/A_i) * sum_v e^{g_i - m_i} (g_i - g_j)

with running max m_i, rescaled partition A_i = sum_v e^{g_i - m_i}
(so Z_i = m_i + log A_i) and a (K x K) cross-accumulator
T_ij = sum_v e^{g_i - m_i} (g_i - g_j), all rescaled when m_i grows.

Grid: (B / bb, V / bv) with the vocab block innermost + sequential; scratch
(m, A, T) persists across vocab blocks in VMEM.  K is small (#clients), so
the T accumulator is (K, K, bb).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kl_kernel(logits_ref, out_ref, m_ref, a_ref, t_ref, *,
               K: int, n_v_blocks: int, inv_temp: float):
    iv = pl.program_id(1)

    @pl.when(iv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        a_ref[...] = jnp.zeros_like(a_ref)
        t_ref[...] = jnp.zeros_like(t_ref)

    g = logits_ref[...].astype(jnp.float32) * inv_temp   # (K, bb, bv)

    m_prev = m_ref[...]                                  # (K, bb)
    m_new = jnp.maximum(m_prev, jnp.max(g, axis=-1))
    scale = jnp.exp(m_prev - m_new)                      # (K, bb)
    e = jnp.exp(g - m_new[..., None])                    # (K, bb, bv)

    a_ref[...] = a_ref[...] * scale + jnp.sum(e, axis=-1)
    m_ref[...] = m_new
    # T_ij += sum_v e_i * (g_i - g_j);   rescale rows by scale_i
    diff = g[:, None, :, :] - g[None, :, :, :]           # (K, K, bb, bv)
    t_ref[...] = t_ref[...] * scale[:, None, :] + \
        jnp.sum(e[:, None, :, :] * diff, axis=-1)

    @pl.when(iv == n_v_blocks - 1)
    def _finish():
        m = m_ref[...]
        a = a_ref[...]
        z = m + jnp.log(a)                               # (K, bb)
        # KL(i||j) = (Z_j - Z_i) + T_ij / A_i
        kl = (z[None, :, :] - z[:, None, :]) + t_ref[...] / a[:, None, :]
        mask = 1.0 - jnp.eye(K, dtype=jnp.float32)       # zero the diagonal
        avg = jnp.sum(kl * mask[:, :, None], axis=1) / max(K - 1, 1)
        out_ref[...] = avg.astype(out_ref.dtype)


def _kl_pair_kernel(live_ref, fixed_ref, w_ref, out_ref,
                    m_ref, a_ref, mf_ref, af_ref, t_ref, *,
                    n_v_blocks: int, inv_temp: float):
    """Rectangular, pair-weighted variant of ``_kl_kernel``:

        out[i, b] = sum_j w[i, j] * KL(P_i(b) || Q_j(b))

    live (Kl, bb, bv) and fixed (Kg, bb, bv) stream together; scratch adds
    a second (m, A) pair for the fixed side and widens the cross
    accumulator to (Kl, Kg, bb).  The training path (Eq. 2 with the j-side
    received) hits this kernel with ``fixed = stop_gradient(live)`` and the
    participation-masked pair weights.
    """
    iv = pl.program_id(1)

    @pl.when(iv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        a_ref[...] = jnp.zeros_like(a_ref)
        mf_ref[...] = jnp.full_like(mf_ref, NEG_INF)
        af_ref[...] = jnp.zeros_like(af_ref)
        t_ref[...] = jnp.zeros_like(t_ref)

    g = live_ref[...].astype(jnp.float32) * inv_temp     # (Kl, bb, bv)
    h = fixed_ref[...].astype(jnp.float32) * inv_temp    # (Kg, bb, bv)

    m_prev = m_ref[...]                                  # (Kl, bb)
    m_new = jnp.maximum(m_prev, jnp.max(g, axis=-1))
    scale = jnp.exp(m_prev - m_new)
    e = jnp.exp(g - m_new[..., None])                    # (Kl, bb, bv)
    a_ref[...] = a_ref[...] * scale + jnp.sum(e, axis=-1)
    m_ref[...] = m_new

    mf_prev = mf_ref[...]                                # (Kg, bb)
    mf_new = jnp.maximum(mf_prev, jnp.max(h, axis=-1))
    ef = jnp.exp(h - mf_new[..., None])
    af_ref[...] = af_ref[...] * jnp.exp(mf_prev - mf_new) + \
        jnp.sum(ef, axis=-1)
    mf_ref[...] = mf_new

    # T_ij += sum_v e_i * (g_i - h_j);   rescale rows by scale_i
    diff = g[:, None, :, :] - h[None, :, :, :]           # (Kl, Kg, bb, bv)
    t_ref[...] = t_ref[...] * scale[:, None, :] + \
        jnp.sum(e[:, None, :, :] * diff, axis=-1)

    @pl.when(iv == n_v_blocks - 1)
    def _finish():
        z = m_ref[...] + jnp.log(a_ref[...])             # (Kl, bb)
        zf = mf_ref[...] + jnp.log(af_ref[...])          # (Kg, bb)
        kl = (zf[None, :, :] - z[:, None, :]) + \
            t_ref[...] / a_ref[...][:, None, :]
        w = w_ref[...].astype(jnp.float32)               # (Kl, Kg)
        out_ref[...] = jnp.sum(kl * w[:, :, None],
                               axis=1).astype(out_ref.dtype)


def _kl_pair_forward(live, fixed, pair_w, temperature: float,
                     interpret: bool, block_b: int, block_v: int):
    Kl, B, V = live.shape
    Kg = fixed.shape[0]
    bb = min(block_b, B)
    bv = min(block_v, V)
    pad_b = (-B) % bb
    pad_v = (-V) % bv
    if pad_b or pad_v:
        pad = ((0, 0), (0, pad_b), (0, pad_v))
        live = jnp.pad(live, pad, constant_values=NEG_INF)
        fixed = jnp.pad(fixed, pad, constant_values=NEG_INF)
    Bp, Vp = B + pad_b, V + pad_v
    n_b, n_v = Bp // bb, Vp // bv

    kernel = functools.partial(_kl_pair_kernel, n_v_blocks=n_v,
                               inv_temp=1.0 / temperature)
    out = pl.pallas_call(
        kernel,
        grid=(n_b, n_v),
        in_specs=[pl.BlockSpec((Kl, bb, bv), lambda ib, iv: (0, ib, iv)),
                  pl.BlockSpec((Kg, bb, bv), lambda ib, iv: (0, ib, iv)),
                  pl.BlockSpec((Kl, Kg), lambda ib, iv: (0, 0))],
        out_specs=pl.BlockSpec((Kl, bb), lambda ib, iv: (0, ib)),
        out_shape=jax.ShapeDtypeStruct((Kl, Bp), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((Kl, bb), jnp.float32),
            pltpu.VMEM((Kl, bb), jnp.float32),
            pltpu.VMEM((Kg, bb), jnp.float32),
            pltpu.VMEM((Kg, bb), jnp.float32),
            pltpu.VMEM((Kl, Kg, bb), jnp.float32),
        ],
        interpret=interpret,
    )(live, fixed, pair_w)
    return out[:, :B]


def _streaming_lse(blocks):
    """Blocked logsumexp: (nv, K, B, bv) -> (K, B), one block resident."""
    K, B = blocks.shape[1], blocks.shape[2]

    def step(carry, blk):
        m, a = carry
        m_new = jnp.maximum(m, jnp.max(blk, axis=-1))
        a = a * jnp.exp(m - m_new) + \
            jnp.sum(jnp.exp(blk - m_new[..., None]), axis=-1)
        return (m_new, a), None

    (m, a), _ = jax.lax.scan(
        step, (jnp.full((K, B), NEG_INF, jnp.float32),
               jnp.zeros((K, B), jnp.float32)), blocks)
    return m + jnp.log(a)


def _streaming_pair_bwd(live, fixed, pair_w, out, g_bar,
                        temperature: float, block_v: int):
    """Backward of the pair-weighted Eq. 2, streamed over vocab blocks.

    Never materialises softmax tensors beyond one (K, B, bv) block; per-
    (client, example) statistics (logsumexp Z, the forward output, the
    weight-contracted cotangents) carry the cross terms:

        dlive[c]  = s * gbar_c * p_c * (R_c*lp_c - (W lq)_c - out_c)
        dfixed[c] = -s * ((W^T (gbar*p))_c - q_c * (W^T gbar)_c)

    with s = 1/T, R = W.sum(1), p/lp live softmax, q/lq fixed softmax.
    """
    Kl, B, V = live.shape
    Kg = fixed.shape[0]
    s = 1.0 / temperature
    w = pair_w.astype(jnp.float32)
    bv = min(block_v, V)
    pad_v = (-V) % bv
    gl = live.astype(jnp.float32) * s
    gf = fixed.astype(jnp.float32) * s
    if pad_v:
        pad = ((0, 0), (0, 0), (0, pad_v))
        gl = jnp.pad(gl, pad, constant_values=NEG_INF)
        gf = jnp.pad(gf, pad, constant_values=NEG_INF)
    n_v = (V + pad_v) // bv
    lb = jnp.moveaxis(gl.reshape(Kl, B, n_v, bv), 2, 0)  # (nv, Kl, B, bv)
    fb = jnp.moveaxis(gf.reshape(Kg, B, n_v, bv), 2, 0)

    z = _streaming_lse(lb)                               # (Kl, B)
    zf = _streaming_lse(fb)                              # (Kg, B)
    r = jnp.sum(w, axis=1)                               # (Kl,)
    gbar = g_bar.astype(jnp.float32)                     # (Kl, B)
    col_gbar = jnp.einsum("ic,ib->cb", w, gbar)          # (Kg, B)
    gs = gbar * s

    def step(_, xs):
        glb, gfb = xs
        lp = glb - z[..., None]                          # (Kl, B, bv)
        p = jnp.exp(lp)
        lq = gfb - zf[..., None]                         # (Kg, B, bv)
        q = jnp.exp(lq)
        # NEG_INF padding is finite (-1e30): p == 0 there, products stay 0
        wlq = jnp.einsum("cj,jbv->cbv", w, lq)
        dlive = gs[..., None] * (r[:, None, None] * p * lp
                                 - p * (wlq + out[..., None]))
        gp = gbar[..., None] * p                         # (Kl, B, bv)
        dfixed = -s * (jnp.einsum("ic,ibv->cbv", w, gp)
                       - q * col_gbar[..., None])
        return None, (dlive, dfixed)

    _, (dl, df) = jax.lax.scan(step, None, (lb, fb))
    dl = jnp.moveaxis(dl, 0, 2).reshape(Kl, B, V + pad_v)[:, :, :V]
    df = jnp.moveaxis(df, 0, 2).reshape(Kg, B, V + pad_v)[:, :, :V]
    return dl.astype(live.dtype), df.astype(fixed.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _kl_pair(live, fixed, pair_w, temperature, interpret, block_b, block_v):
    return _kl_pair_forward(live, fixed, pair_w, temperature, interpret,
                            block_b, block_v)


def _kl_pair_fwd(live, fixed, pair_w, temperature, interpret, block_b,
                 block_v):
    out = _kl_pair_forward(live, fixed, pair_w, temperature, interpret,
                           block_b, block_v)
    return out, (live, fixed, pair_w, out)


def _kl_pair_bwd(temperature, interpret, block_b, block_v, res, g_bar):
    live, fixed, pair_w, out = res
    dlive, dfixed = _streaming_pair_bwd(live, fixed, pair_w, out, g_bar,
                                        temperature, block_v)
    # pair weights are data (masks/averaging constants), not parameters
    return dlive, dfixed, jnp.zeros_like(pair_w)


_kl_pair.defvjp(_kl_pair_fwd, _kl_pair_bwd)


def kl_mutual_pair(live, fixed, pair_w, *, temperature: float = 1.0,
                   block_b: int = 128, block_v: int = 2048,
                   interpret: bool = False):
    """Differentiable pair-weighted Eq. 2 via the fused streaming kernel.

    live (Kl, B, V) x fixed (Kg, B, V) with (Kl, Kg) pair weights ->
    (Kl, B).  Carries a ``jax.custom_vjp`` whose backward streams over
    vocab blocks (``_streaming_pair_bwd``) — the Eq.-2 TRAINING path: pass
    ``fixed = stop_gradient(live)`` (or received predictions) and the
    fixed-side cotangent is simply dropped.  Cotangent for ``pair_w`` is
    defined as zero.
    """
    return _kl_pair(live, fixed, pair_w, float(temperature),
                    bool(interpret), int(block_b), int(block_v))


def kl_mutual(logits, *, temperature: float = 1.0,
              block_b: int = 128, block_v: int = 2048,
              interpret: bool = False):
    """logits: (K, B, V) -> (K, B) average pairwise KL per example."""
    K, B, V = logits.shape
    bb = min(block_b, B)
    bv = min(block_v, V)
    pad_b = (-B) % bb
    pad_v = (-V) % bv
    if pad_b or pad_v:
        # vocab padding uses NEG_INF so e -> 0 and (identical) diffs -> 0
        logits = jnp.pad(logits, ((0, 0), (0, pad_b), (0, pad_v)),
                         constant_values=NEG_INF)
    Bp, Vp = B + pad_b, V + pad_v
    n_b, n_v = Bp // bb, Vp // bv

    kernel = functools.partial(_kl_kernel, K=K, n_v_blocks=n_v,
                               inv_temp=1.0 / temperature)
    out = pl.pallas_call(
        kernel,
        grid=(n_b, n_v),
        in_specs=[pl.BlockSpec((K, bb, bv), lambda ib, iv: (0, ib, iv))],
        out_specs=pl.BlockSpec((K, bb), lambda ib, iv: (0, ib)),
        out_shape=jax.ShapeDtypeStruct((K, Bp), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((K, bb), jnp.float32),
            pltpu.VMEM((K, bb), jnp.float32),
            pltpu.VMEM((K, K, bb), jnp.float32),
        ],
        interpret=interpret,
    )(logits)
    return out[:, :B]
