"""Fused mutual-learning KL Pallas kernel (paper Eq. 2 at vocab scale).

Computes, for client-stacked logits (K, B, V):

    out[i, b] = 1/(K-1) * sum_{j != i} KL(P_i(b) || P_j(b))

in ONE streaming pass over the vocabulary — no K softmax tensors ever hit
HBM.  Uses a flash-style online decomposition:

    KL(P_i || P_j) = (Z_j - Z_i) + (1/A_i) * sum_v e^{g_i - m_i} (g_i - g_j)

with running max m_i, rescaled partition A_i = sum_v e^{g_i - m_i}
(so Z_i = m_i + log A_i) and a (K x K) cross-accumulator
T_ij = sum_v e^{g_i - m_i} (g_i - g_j), all rescaled when m_i grows.

Grid: (B / bb, V / bv) with the vocab block innermost + sequential; scratch
(m, A, T) persists across vocab blocks in VMEM.  K is small (#clients), so
the T accumulator is (K, K, bb).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kl_kernel(logits_ref, out_ref, m_ref, a_ref, t_ref, *,
               K: int, n_v_blocks: int, inv_temp: float):
    iv = pl.program_id(1)

    @pl.when(iv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        a_ref[...] = jnp.zeros_like(a_ref)
        t_ref[...] = jnp.zeros_like(t_ref)

    g = logits_ref[...].astype(jnp.float32) * inv_temp   # (K, bb, bv)

    m_prev = m_ref[...]                                  # (K, bb)
    m_new = jnp.maximum(m_prev, jnp.max(g, axis=-1))
    scale = jnp.exp(m_prev - m_new)                      # (K, bb)
    e = jnp.exp(g - m_new[..., None])                    # (K, bb, bv)

    a_ref[...] = a_ref[...] * scale + jnp.sum(e, axis=-1)
    m_ref[...] = m_new
    # T_ij += sum_v e_i * (g_i - g_j);   rescale rows by scale_i
    diff = g[:, None, :, :] - g[None, :, :, :]           # (K, K, bb, bv)
    t_ref[...] = t_ref[...] * scale[:, None, :] + \
        jnp.sum(e[:, None, :, :] * diff, axis=-1)

    @pl.when(iv == n_v_blocks - 1)
    def _finish():
        m = m_ref[...]
        a = a_ref[...]
        z = m + jnp.log(a)                               # (K, bb)
        # KL(i||j) = (Z_j - Z_i) + T_ij / A_i
        kl = (z[None, :, :] - z[:, None, :]) + t_ref[...] / a[:, None, :]
        mask = 1.0 - jnp.eye(K, dtype=jnp.float32)       # zero the diagonal
        avg = jnp.sum(kl * mask[:, :, None], axis=1) / max(K - 1, 1)
        out_ref[...] = avg.astype(out_ref.dtype)


def kl_mutual(logits, *, temperature: float = 1.0,
              block_b: int = 128, block_v: int = 2048,
              interpret: bool = False):
    """logits: (K, B, V) -> (K, B) average pairwise KL per example."""
    K, B, V = logits.shape
    bb = min(block_b, B)
    bv = min(block_v, V)
    pad_b = (-B) % bb
    pad_v = (-V) % bv
    if pad_b or pad_v:
        # vocab padding uses NEG_INF so e -> 0 and (identical) diffs -> 0
        logits = jnp.pad(logits, ((0, 0), (0, pad_b), (0, pad_v)),
                         constant_values=NEG_INF)
    Bp, Vp = B + pad_b, V + pad_v
    n_b, n_v = Bp // bb, Vp // bv

    kernel = functools.partial(_kl_kernel, K=K, n_v_blocks=n_v,
                               inv_temp=1.0 / temperature)
    out = pl.pallas_call(
        kernel,
        grid=(n_b, n_v),
        in_specs=[pl.BlockSpec((K, bb, bv), lambda ib, iv: (0, ib, iv))],
        out_specs=pl.BlockSpec((K, bb), lambda ib, iv: (0, ib)),
        out_shape=jax.ShapeDtypeStruct((K, Bp), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((K, bb), jnp.float32),
            pltpu.VMEM((K, bb), jnp.float32),
            pltpu.VMEM((K, K, bb), jnp.float32),
        ],
        interpret=interpret,
    )(logits)
    return out[:, :B]
