"""Mamba2 SSD chunked-scan Pallas kernel.

Grid (B, H, n_chunks) with the chunk index innermost + sequential; the
(P x N) SSM state lives in VMEM scratch and is carried across chunk
iterations — the TPU-native replacement for the paper-family's CUDA
selective-scan: sequential grid + VMEM-resident state instead of
warp-level scans.

Per chunk of length L (math identical to ref.ssd):
    y_intra[t] = sum_{s<=t} (C_t . B_s) e^{cs_t - cs_s} dt_s x_s
    y_inter[t] = e^{cs_t} * C_t . state_in
    state_out  = e^{cs_L} state_in + sum_t e^{cs_L - cs_t} dt_t B_t x_t^T

Inputs are pre-chunked by the wrapper to (B, H, nc, L, ...) so every block
is contiguous; B/C arrive group-expanded per head (the wrapper indexes the
group in the BlockSpec index_map, so no materialised repeat).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref,
                state_ref, *, L: int, n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)               # (L, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)             # (L,)... stored (L,1)
    dt = dt[:, 0]
    a = a_ref[0, 0].astype(jnp.float32)                  # scalar
    bmat = b_ref[0, 0, 0].astype(jnp.float32)            # (L, N)
    cmat = c_ref[0, 0, 0].astype(jnp.float32)            # (L, N)

    da = dt * a                                          # (L,)  <= 0
    cs = jnp.cumsum(da)                                  # (L,)

    # intra-chunk
    scores = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())))  # (L,L)
    # clamp the (masked) upper triangle before exp: inf * 0 would be NaN
    decay = jnp.exp(jnp.minimum(cs[:, None] - cs[None, :], 0.0))
    tri = jnp.tril(jnp.ones((L, L), jnp.float32))
    w = scores * decay * dt[None, :] * tri
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())))            # (L,P)

    # inter-chunk
    state = state_ref[...]                               # (P, N)
    y += jnp.exp(cs)[:, None] * jax.lax.dot_general(
        cmat, state, (((1,), (1,)), ((), ())))           # (L,N)x(P,N)^T

    # state update
    tail = jnp.exp(cs[-1] - cs) * dt                     # (L,)
    state_ref[...] = jnp.exp(cs[-1]) * state + jax.lax.dot_general(
        x, bmat * tail[:, None], (((0,), (0,)), ((), ())))  # (P, N)

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    @pl.when(ic == n_chunks - 1)
    def _finish():
        state_out_ref[0, 0] = state_ref[...].astype(state_out_ref.dtype)


def ssd_scan(x, dt, A, B_mat, C_mat, *, chunk: int = 256,
             interpret: bool = False):
    """Pallas SSD.  Same contract as ref.ssd (zero initial state).

    x (B,S,H,P), dt (B,S,H), A (H,), B/C (B,S,G,N) -> y (B,S,H,P),
    final_state (B,H,P,N) fp32.
    """
    Bb, S, H, Pd = x.shape
    G, N = B_mat.shape[2], B_mat.shape[3]
    rep = H // G
    L = min(chunk, S)
    pad = (-S) % L
    if pad:
        zf = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        x, dt, B_mat, C_mat = map(zf, (x, dt, B_mat, C_mat))
    Sp = S + pad
    nc = Sp // L

    # pre-chunk to (B, H, nc, L, ...) / (B, G, nc, L, N)
    xc = x.reshape(Bb, nc, L, H, Pd).transpose(0, 3, 1, 2, 4)
    dtc = dt.reshape(Bb, nc, L, H).transpose(0, 3, 1, 2)[..., None]  # (B,H,nc,L,1)
    bc = B_mat.reshape(Bb, nc, L, G, N).transpose(0, 3, 1, 2, 4)
    cc = C_mat.reshape(Bb, nc, L, G, N).transpose(0, 3, 1, 2, 4)
    a2 = A.reshape(H, 1)

    kernel = functools.partial(_ssd_kernel, L=L, n_chunks=nc)
    y, state = pl.pallas_call(
        kernel,
        grid=(Bb, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, L, Pd), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, L, 1), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, 1, 1, L, N),
                         lambda b, h, c, r=rep: (b, h // r, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, L, N),
                         lambda b, h, c, r=rep: (b, h // r, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, L, Pd), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, Pd, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, H, nc, L, Pd), x.dtype),
            jax.ShapeDtypeStruct((Bb, H, Pd, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((Pd, N), jnp.float32)],
        interpret=interpret,
    )(xc, dtc, a2, bc, cc)
    y = y.transpose(0, 2, 3, 1, 4).reshape(Bb, Sp, H, Pd)[:, :S]
    return y, state
