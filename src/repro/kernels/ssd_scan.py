"""Mamba2 SSD chunked-scan Pallas kernel.

Grid (B, H, n_chunks) with the chunk index innermost + sequential; the
(P x N) SSM state lives in VMEM scratch and is carried across chunk
iterations — the TPU-native replacement for the paper-family's CUDA
selective-scan: sequential grid + VMEM-resident state instead of
warp-level scans.

Per chunk of length L (math identical to ref.ssd):
    y_intra[t] = sum_{s<=t} (C_t . B_s) e^{cs_t - cs_s} dt_s x_s
    y_inter[t] = e^{cs_t} * C_t . state_in
    state_out  = e^{cs_L} state_in + sum_t e^{cs_L - cs_t} dt_t B_t x_t^T

Inputs are pre-chunked by the wrapper to (B, H, nc, L, ...) so every block
is contiguous; B/C arrive group-expanded per head (the wrapper indexes the
group in the BlockSpec index_map, so no materialised repeat).

DIFFERENTIABLE: the forward additionally emits every chunk's ENTRY state
(B, H, nc, P, N), and ``ssd_scan`` carries a ``jax.custom_vjp`` whose
backward replays the chunks in reverse (``_ssd_chunk_bwd``): each chunk's
local VJP is recomputed from its saved boundary state via ``jax.vjp`` of
the plain-jnp chunk map, and the state cotangent flows chunk-to-chunk in
the carry — the chunked analogue of the flash recompute backward.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref,
                states_in_ref, state_ref, *, L: int, n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    # record the chunk-ENTRY state before updating it: the backward's
    # boundary residual (one (P, N) tile per chunk, nothing per-token)
    states_in_ref[0, 0, 0] = state_ref[...]

    x = x_ref[0, 0, 0].astype(jnp.float32)               # (L, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)             # (L,)... stored (L,1)
    dt = dt[:, 0]
    a = a_ref[0, 0].astype(jnp.float32)                  # scalar
    bmat = b_ref[0, 0, 0].astype(jnp.float32)            # (L, N)
    cmat = c_ref[0, 0, 0].astype(jnp.float32)            # (L, N)

    da = dt * a                                          # (L,)  <= 0
    cs = jnp.cumsum(da)                                  # (L,)

    # intra-chunk
    scores = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())))  # (L,L)
    # clamp the (masked) upper triangle before exp: inf * 0 would be NaN
    decay = jnp.exp(jnp.minimum(cs[:, None] - cs[None, :], 0.0))
    tri = jnp.tril(jnp.ones((L, L), jnp.float32))
    w = scores * decay * dt[None, :] * tri
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())))            # (L,P)

    # inter-chunk
    state = state_ref[...]                               # (P, N)
    y += jnp.exp(cs)[:, None] * jax.lax.dot_general(
        cmat, state, (((1,), (1,)), ((), ())))           # (L,N)x(P,N)^T

    # state update
    tail = jnp.exp(cs[-1] - cs) * dt                     # (L,)
    state_ref[...] = jnp.exp(cs[-1]) * state + jax.lax.dot_general(
        x, bmat * tail[:, None], (((0,), (0,)), ((), ())))  # (P, N)

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    @pl.when(ic == n_chunks - 1)
    def _finish():
        state_out_ref[0, 0] = state_ref[...].astype(state_out_ref.dtype)


def _ssd_forward(x, dt, A, B_mat, C_mat, chunk: int, interpret: bool):
    """Pallas SSD -> (y, final_state, chunk-entry states (B,H,nc,P,N))."""
    Bb, S, H, Pd = x.shape
    G, N = B_mat.shape[2], B_mat.shape[3]
    rep = H // G
    L = min(chunk, S)
    pad = (-S) % L
    if pad:
        zf = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        x, dt, B_mat, C_mat = map(zf, (x, dt, B_mat, C_mat))
    Sp = S + pad
    nc = Sp // L

    # pre-chunk to (B, H, nc, L, ...) / (B, G, nc, L, N)
    xc = x.reshape(Bb, nc, L, H, Pd).transpose(0, 3, 1, 2, 4)
    dtc = dt.reshape(Bb, nc, L, H).transpose(0, 3, 1, 2)[..., None]  # (B,H,nc,L,1)
    bc = B_mat.reshape(Bb, nc, L, G, N).transpose(0, 3, 1, 2, 4)
    cc = C_mat.reshape(Bb, nc, L, G, N).transpose(0, 3, 1, 2, 4)
    a2 = A.reshape(H, 1)

    kernel = functools.partial(_ssd_kernel, L=L, n_chunks=nc)
    y, state, states_in = pl.pallas_call(
        kernel,
        grid=(Bb, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, L, Pd), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, L, 1), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, 1, 1, L, N),
                         lambda b, h, c, r=rep: (b, h // r, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, L, N),
                         lambda b, h, c, r=rep: (b, h // r, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, L, Pd), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, Pd, N), lambda b, h, c: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, Pd, N), lambda b, h, c: (b, h, c, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, H, nc, L, Pd), x.dtype),
            jax.ShapeDtypeStruct((Bb, H, Pd, N), jnp.float32),
            jax.ShapeDtypeStruct((Bb, H, nc, Pd, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((Pd, N), jnp.float32)],
        interpret=interpret,
    )(xc, dtc, a2, bc, cc)
    y = y.transpose(0, 2, 3, 1, 4).reshape(Bb, Sp, H, Pd)[:, :S]
    return y, state, states_in


def _ssd_chunk(x_c, dt_c, b_c, c_c, a, state):
    """One chunk of the SSD map in plain jnp — ref.ssd's chunk_body with the
    head-group repeat folded in.  x_c (B,L,H,P), dt_c (B,L,H), b_c/c_c
    (B,L,G,N), a (H,), state (B,H,P,N) -> (y (B,L,H,P), state_out)."""
    L, H = x_c.shape[1], x_c.shape[2]
    rep = H // b_c.shape[2]
    Bc_ = jnp.repeat(b_c, rep, axis=2)
    Cc_ = jnp.repeat(c_c, rep, axis=2)
    cs_ = jnp.cumsum(dt_c * a, axis=1)                   # (B,L,H)
    scores = jnp.einsum("blhn,bshn->bhls", Cc_, Bc_)
    expo = cs_[:, :, None, :] - cs_[:, None, :, :]       # (B,t,s,H)
    decay = jnp.transpose(jnp.exp(jnp.minimum(expo, 0.0)), (0, 3, 1, 2))
    tri = jnp.tril(jnp.ones((L, L), jnp.float32))
    w = scores * decay * jnp.transpose(dt_c, (0, 2, 1))[:, :, None, :] * tri
    y = jnp.einsum("bhls,bshp->blhp", w, x_c)
    y += jnp.einsum("blhn,bhpn->blhp", Cc_, state) * jnp.exp(cs_)[..., None]
    tail = jnp.exp(cs_[:, -1:, :] - cs_) * dt_c          # (B,L,H)
    state = jnp.exp(cs_[:, -1, :])[:, :, None, None] * state + \
        jnp.einsum("blhn,blhp,blh->bhpn", Bc_, x_c, tail)
    return y, state


def _ssd_chunk_bwd(x, dt, A, B_mat, C_mat, states_in, dy, dstate_out,
                   chunk: int):
    """Backward of the chunked scan: reverse lax.scan over chunks, each
    chunk's VJP recomputed (``jax.vjp`` of ``_ssd_chunk``) from the
    forward's saved chunk-ENTRY state; the state cotangent is the carry and
    dA accumulates across chunks."""
    Bb, S, H, Pd = x.shape
    G, N = B_mat.shape[2], B_mat.shape[3]
    L = min(chunk, S)
    pad = (-S) % L
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = B_mat.astype(jnp.float32)
    cf = C_mat.astype(jnp.float32)
    af = A.astype(jnp.float32)
    dyf = dy.astype(jnp.float32)
    if pad:
        zf = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        xf, dtf, bf, cf, dyf = map(zf, (xf, dtf, bf, cf, dyf))
    Sp = S + pad
    nc = Sp // L
    # chunk axis leading: (nc, B, L, ...) / (nc, B, H, P, N)
    xc = jnp.moveaxis(xf.reshape(Bb, nc, L, H, Pd), 1, 0)
    dtc = jnp.moveaxis(dtf.reshape(Bb, nc, L, H), 1, 0)
    bc = jnp.moveaxis(bf.reshape(Bb, nc, L, G, N), 1, 0)
    cc = jnp.moveaxis(cf.reshape(Bb, nc, L, G, N), 1, 0)
    stc = jnp.moveaxis(states_in.astype(jnp.float32), 2, 0)
    dyc = jnp.moveaxis(dyf.reshape(Bb, nc, L, H, Pd), 1, 0)

    def step(carry, xs):
        dstate, da_acc = carry
        x_c, dt_c, b_c, c_c, st_in, dy_c = xs
        _, vjp = jax.vjp(_ssd_chunk, x_c, dt_c, b_c, c_c, af, st_in)
        dx_c, ddt_c, db_c, dc_c, da_c, dstate_prev = vjp((dy_c, dstate))
        return (dstate_prev, da_acc + da_c), (dx_c, ddt_c, db_c, dc_c)

    (_, da), (dxc, ddtc, dbc, dcc) = jax.lax.scan(
        step, (dstate_out.astype(jnp.float32), jnp.zeros_like(af)),
        (xc, dtc, bc, cc, stc, dyc), reverse=True)
    dx = jnp.moveaxis(dxc, 0, 1).reshape(Bb, Sp, H, Pd)[:, :S]
    ddt = jnp.moveaxis(ddtc, 0, 1).reshape(Bb, Sp, H)[:, :S]
    db = jnp.moveaxis(dbc, 0, 1).reshape(Bb, Sp, G, N)[:, :S]
    dc = jnp.moveaxis(dcc, 0, 1).reshape(Bb, Sp, G, N)[:, :S]
    return (dx.astype(x.dtype), ddt.astype(dt.dtype), da.astype(A.dtype),
            db.astype(B_mat.dtype), dc.astype(C_mat.dtype))


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _ssd(x, dt, A, B_mat, C_mat, chunk, interpret):
    y, state, _ = _ssd_forward(x, dt, A, B_mat, C_mat, chunk, interpret)
    return y, state


def _ssd_fwd(x, dt, A, B_mat, C_mat, chunk, interpret):
    y, state, states_in = _ssd_forward(x, dt, A, B_mat, C_mat, chunk,
                                       interpret)
    return (y, state), (x, dt, A, B_mat, C_mat, states_in)


def _ssd_bwd(chunk, interpret, res, cts):
    x, dt, A, B_mat, C_mat, states_in = res
    dy, dstate_out = cts
    return _ssd_chunk_bwd(x, dt, A, B_mat, C_mat, states_in, dy, dstate_out,
                          chunk)


_ssd.defvjp(_ssd_fwd, _ssd_bwd)


def ssd_scan(x, dt, A, B_mat, C_mat, *, chunk: int = 256,
             interpret: bool = False):
    """Pallas SSD.  Same contract as ref.ssd (zero initial state).

    x (B,S,H,P), dt (B,S,H), A (H,), B/C (B,S,G,N) -> y (B,S,H,P),
    final_state (B,H,P,N) fp32.  Differentiable in every tensor input
    (``jax.custom_vjp`` with the chunked reverse-scan backward).
    """
    return _ssd(x, dt, A, B_mat, C_mat, int(chunk), bool(interpret))
