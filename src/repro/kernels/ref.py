"""Pure-jnp oracles for every Pallas kernel.

These are the semantic ground truth: kernel tests assert_allclose against
them, and the dry-run lowers them (XLA-native) so roofline numbers reflect
the compiler's own scheduling.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# attention

def attention(q, k, v, *, causal: bool = True,
              window: Optional[int] = None,
              positions_q=None, positions_k=None):
    """Grouped-query attention oracle.

    q: (B, S, Hq, hd);  k, v: (B, T, Hkv, hd);  Hq % Hkv == 0.
    positions_*: optional absolute positions (B, S)/(B, T); entries < 0 in
    positions_k mark invalid (unwritten) cache slots.  Without positions,
    q/k index within the array is the position (self-attention).
    Returns (B, S, Hq, hd) in q.dtype; softmax in fp32.
    """
    B, S, Hq, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qf = q.reshape(B, S, Hkv, G, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bskgh,btkh->bksgt", qf, kf) * (hd ** -0.5)

    if positions_q is None:
        positions_q = jnp.broadcast_to(jnp.arange(S), (B, S))
    if positions_k is None:
        positions_k = jnp.broadcast_to(jnp.arange(T), (B, T))
    pq = positions_q[:, None, :, None, None]            # (B,1,S,1,1)
    pk = positions_k[:, None, None, None, :]            # (B,1,1,1,T)
    mask = pk >= 0
    if causal:
        mask &= pk <= pq
    if window is not None:
        mask &= pq - pk < window
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bksgt,btkh->bskgh", probs, vf)
    return out.reshape(B, S, Hq, hd).astype(q.dtype)


def attention_xla_flash(q, k, v, *, causal: bool = True,
                        window=None, block_k: int = 512):
    """Online-softmax attention in pure jnp (lax.scan over KV blocks).

    XLA-lowerable flash algorithm: never materialises the (S, T) score
    matrix, so the dry-run's memory/HLO-bytes terms reflect the Pallas
    kernel's behaviour instead of the O(S^2) oracle.  Same contract as
    ``attention`` for the self-attention (train/prefill) case.
    """
    B, S, Hq, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    bk = min(block_k, T)
    pad = (-T) % bk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nblk = (T + pad) // bk
    qf = q.reshape(B, S, Hkv, G, hd).astype(jnp.float32) * (hd ** -0.5)
    kb = k.reshape(B, nblk, bk, Hkv, hd).astype(jnp.float32)
    vb = v.reshape(B, nblk, bk, Hkv, hd).astype(jnp.float32)
    qpos = jnp.arange(S)

    def body(carry, kblk, vblk, jblk):
        m, l, acc = carry
        s = jnp.einsum("bskgh,btkh->bksgt", qf, kblk)   # (B,Hkv,S,G,bk)
        kpos = jblk * bk + jnp.arange(bk)
        mask = kpos[None, :] < T
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= qpos[:, None] - kpos[None, :] < window
        s = jnp.where(mask[None, None, :, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        scale = jnp.exp(m - m_new)
        l = l * scale + jnp.sum(p, axis=-1)
        acc = acc * scale[..., None] + jnp.einsum("bksgt,btkh->bksgh", p, vblk)
        return (m_new, l, acc)

    m = jnp.full((B, Hkv, S, G), NEG_INF, jnp.float32)
    l = jnp.zeros((B, Hkv, S, G), jnp.float32)
    acc = jnp.zeros((B, Hkv, S, G, hd), jnp.float32)
    # static Python loop, NOT lax.scan: XLA's cost analysis counts a scan
    # body once regardless of trip count, which would corrupt the dry-run's
    # roofline terms (the blocks stay fused either way).
    for j in range(nblk):
        m, l, acc = body((m, l, acc), kb[:, j], vb[:, j], j)
    out = acc / jnp.maximum(l, 1e-30)[..., None]        # (B,Hkv,S,G,hd)
    out = jnp.moveaxis(out, 1, 2).reshape(B, S, Hq, hd)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# mutual-learning KL (the paper's Eq. 2 at vocabulary scale)

def mutual_kl(logits, temperature: float = 1.0):
    """Average pairwise KL of each client against the rest.

    logits: (K, B, V).  Returns (K, B):
        out[i, b] = 1/(K-1) * sum_{j != i} KL(P_i(b) || P_j(b))
    with P = softmax(logits / T).  fp32 internally.
    """
    K = logits.shape[0]
    lf = logits.astype(jnp.float32) / temperature
    logp = jax.nn.log_softmax(lf, axis=-1)              # (K,B,V)
    p = jnp.exp(logp)
    self_term = jnp.sum(p * logp, axis=-1)              # (K,B)
    cross = jnp.einsum("ibv,jbv->ijb", p, logp)         # (i,j,B)
    kl = self_term[:, None, :] - cross                  # KL(i||j)
    mask = (1.0 - jnp.eye(K))[:, :, None]
    denom = max(K - 1, 1)
    return jnp.sum(kl * mask, axis=1) / denom


def mutual_kl_pair(live, fixed, pair_w, temperature: float = 1.0):
    """Pair-weighted rectangular Eq. 2 oracle.

    live: (Kl, B, V) — differentiable side.  fixed: (Kg, B, V).
    pair_w: (Kl, Kg) weights (e.g. the masked 1/(M-1) average).  Returns
    (Kl, B): out[i, b] = sum_j pair_w[i, j] * KL(P_i(b) || Q_j(b)).
    ``mutual_kl(x) == mutual_kl_pair(x, x, (1 - I) / (K - 1))``.
    """
    lp_live = jax.nn.log_softmax(
        live.astype(jnp.float32) / temperature, axis=-1)
    p_live = jnp.exp(lp_live)
    lp_fixed = jax.nn.log_softmax(
        fixed.astype(jnp.float32) / temperature, axis=-1)
    self_term = jnp.sum(p_live * lp_live, axis=-1)          # (Kl,B)
    cross = jnp.einsum("ibv,jbv->ijb", p_live, lp_fixed)    # (i,j,B)
    kl = self_term[:, None, :] - cross
    return jnp.sum(kl * pair_w.astype(jnp.float32)[:, :, None], axis=1)


def sparse_kl_pair(live, idx, logp_top, pair_w, temperature: float = 1.0):
    """Pair-weighted Eq. 2 against RECEIVED sparse (top-k) predictions.

    live: (Kl, B, V) — differentiable side.  idx, logp_top: (J, B, k) — the
    shared top-k sets.  pair_w: (Kl, J) weights.  Returns (Kl, B):

        out[i, b] = sum_j w[i, j] * KL(P_i(b) || ~Q_j(b))

    with ~Q_j = top-k mass of Q_j + uniform tail over the V - k residual
    (the SparseDML reconstruction), i.e. per pair

        KL_ij = -H(P_i) - c_j (1 - s_ij) - sum_t p_i[idx_j,t] logp_j[t]

    where s_ij = sum_t p_i[idx_j,t] and c_j = log(residual_j / (V - k)).
    This is the semantic ground truth for ``kernels.sparse_kl``; both
    ``core.mutual.sparse_mutual_kl_loss`` (w = (1-I)/(K-1), mean over B)
    and ``core.mutual.sparse_kl_to_received`` (Kl = 1, w = 1/J) reduce
    to it.
    """
    Kl, B, V = live.shape
    k = idx.shape[-1]
    lp_live = jax.nn.log_softmax(
        live.astype(jnp.float32) / temperature, axis=-1)
    p_live = jnp.exp(lp_live)                            # (Kl,B,V)
    neg_h = jnp.sum(p_live * lp_live, axis=-1)           # (Kl,B)
    logp = logp_top.astype(jnp.float32)                  # (J,B,k)
    residual = jnp.clip(1.0 - jnp.sum(jnp.exp(logp), axis=-1), 1e-9, 1.0)
    c = jnp.log(residual / max(V - k, 1))                # (J,B)
    # p_at[i, j, b, t] = p_live[i, b, idx[j, b, t]]
    p_at = jax.vmap(lambda pi: jax.vmap(
        lambda ij: jnp.take_along_axis(pi, ij, axis=-1))(idx))(p_live)
    s = jnp.sum(p_at, axis=-1)                           # (Kl,J,B)
    cross = jnp.sum(p_at * logp[None], axis=-1)          # (Kl,J,B)
    kl = neg_h[:, None, :] - c[None] * (1.0 - s) - cross
    return jnp.einsum("ij,ijb->ib", pair_w.astype(jnp.float32), kl)


def bernoulli_mutual_kl(probs):
    """Eq. 2 for the paper's sigmoid binary head.  probs: (K, B) in (0,1)."""
    K = probs.shape[0]
    p = jnp.clip(probs.astype(jnp.float32), 1e-7, 1 - 1e-7)
    pi = p[:, None, :]                                   # (i,1,B)
    pj = p[None, :, :]                                   # (1,j,B)
    kl = pi * jnp.log(pi / pj) + (1 - pi) * jnp.log((1 - pi) / (1 - pj))
    mask = (1.0 - jnp.eye(K))[:, :, None]
    return jnp.sum(kl * mask, axis=1) / max(K - 1, 1)


# ---------------------------------------------------------------------------
# Mamba2 SSD (state-space duality) chunked scan

def ssd(x, dt, A, B_mat, C_mat, *, chunk: int = 256,
        initial_state=None) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan oracle.

    x:     (B, S, H, P)   pre-gated inputs
    dt:    (B, S, H)      positive step sizes (softplus already applied)
    A:     (H,)           negative decay rates
    B_mat: (B, S, G, N)   input projections (G groups, H % G == 0)
    C_mat: (B, S, G, N)   output projections
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bb, S, H, Pd = x.shape
    G, N = B_mat.shape[2], B_mat.shape[3]
    rep = H // G
    pad = (-S) % chunk
    if pad:
        zf = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        x, dt, B_mat, C_mat = map(zf, (x, dt, B_mat, C_mat))
    Sp = S + pad
    nc = Sp // chunk
    xc = x.reshape(Bb, nc, chunk, H, Pd).astype(jnp.float32)
    dtc = dt.reshape(Bb, nc, chunk, H).astype(jnp.float32)
    Bc = jnp.repeat(B_mat.reshape(Bb, nc, chunk, G, N), rep, axis=3).astype(jnp.float32)
    Cc = jnp.repeat(C_mat.reshape(Bb, nc, chunk, G, N), rep, axis=3).astype(jnp.float32)
    Af = A.astype(jnp.float32)

    dA = dtc * Af                                        # (B,nc,L,H) <= 0
    cs = jnp.cumsum(dA, axis=2)                          # within-chunk cumsum

    if initial_state is None:
        initial_state = jnp.zeros((Bb, H, Pd, N), jnp.float32)

    def chunk_body(state, inp):
        xc_, dtc_, Bc_, Cc_, cs_ = inp                   # leading dim = B
        L = xc_.shape[1]
        # intra-chunk: M[t,s] = C_t.B_s * exp(cs_t - cs_s) * dt_s,  s <= t
        scores = jnp.einsum("blhn,bshn->bhls", Cc_, Bc_)
        # exponent is <= 0 only on the causal (t >= s) triangle; clamp the
        # masked half before exp so inf * 0 never produces NaN
        expo = cs_[:, :, None, :] - cs_[:, None, :, :]              # (B,t,s,H)
        decay = jnp.exp(jnp.minimum(expo, 0.0))
        decay = jnp.transpose(decay, (0, 3, 1, 2))                  # (B,H,t,s)
        tri = jnp.tril(jnp.ones((L, L), jnp.float32))
        w = scores * decay * jnp.transpose(dtc_, (0, 2, 1))[:, :, None, :] * tri
        y_intra = jnp.einsum("bhls,bshp->blhp", w, xc_)
        # inter-chunk: y += exp(cs_t) * C_t . state
        y_inter = jnp.einsum("blhn,bhpn->blhp", Cc_, state) \
            * jnp.exp(cs_)[..., None]
        # state update
        tail = jnp.exp(cs_[:, -1:, :] - cs_) * dtc_                  # (B,L,H)
        state = jnp.exp(cs_[:, -1, :])[:, :, None, None] * state + \
            jnp.einsum("blhn,blhp,blh->bhpn", Bc_, xc_, tail)
        return state, y_intra + y_inter

    xs = (jnp.swapaxes(xc, 0, 1), jnp.swapaxes(dtc, 0, 1),
          jnp.swapaxes(Bc, 0, 1), jnp.swapaxes(Cc, 0, 1),
          jnp.swapaxes(cs, 0, 1))
    final_state, ys = jax.lax.scan(chunk_body, initial_state, xs)
    y = jnp.swapaxes(ys, 0, 1).reshape(Bb, Sp, H, Pd)[:, :S]
    return y.astype(x.dtype), final_state
