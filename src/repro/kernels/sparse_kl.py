"""Fused top-k-gather + sparse-KL Pallas kernel (the SparseDML hot path).

Computes, for live logits (Kl, B, V) against J received top-k prediction
sets idx/logp_top (J, B, k) with pair weights (Kl, J):

    out[i, b] = sum_j w[i, j] * KL(P_i(b) || ~Q_j(b))

where ~Q_j is the SparseDML reconstruction (top-k mass + uniform tail over
the V - k residual).  Per pair the KL decomposes into terms that only ever
need a single streaming pass over the vocabulary:

    KL_ij = -H(P_i) - c_j (1 - s_ij) - sum_t p_i[idx_j,t] logp_j[t]

  * -H(P_i) via flash-style online softmax: running max m (Kl, bb),
    rescaled partition A and entropy accumulator U = sum_v e^{g-m} g
    (so  -H = U/A - Z  with  Z = m + log A);
  * the gathers via a raw scaled-logit accumulator gat[i, j, b, t]
    += sum_v 1[idx_jt == v] g_ibv — each received index lands in exactly
    ONE vocab block, so gat accumulates without rescaling and
    p_i[idx] = exp(gat - Z) at the end;
  * c_j, s_ij and the cross term close the formula in the final block.

No softmax tensor ever hits HBM: FLOPs and traffic are O(B·V·(Kl + J·k/bv))
for the streaming pass versus the unfused XLA path's softmax
materialisation + J separate (K, B, k) gathers over a resident (K, B, V)
probability tensor.  With k << V the per-round mutual-step cost scales
with k, matching the comm-side V/(2k) reduction (EXPERIMENTS.md §Perf).

Grid: (B / bb, V / bv), vocab block innermost + sequential; scratch
(m, A, U, gat) persists across vocab blocks in VMEM.  The backward is a
plain-JAX streamed pass (``jax.custom_vjp``; one (Kl, B, bv) block
resident), mirroring ``kl_mutual._streaming_pair_bwd``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _sparse_kl_kernel(live_ref, idx_ref, logp_ref, w_ref, out_ref,
                      m_ref, a_ref, u_ref, gat_ref, *,
                      n_v_blocks: int, inv_temp: float, V: int, k: int):
    iv = pl.program_id(1)

    @pl.when(iv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        a_ref[...] = jnp.zeros_like(a_ref)
        u_ref[...] = jnp.zeros_like(u_ref)
        gat_ref[...] = jnp.zeros_like(gat_ref)

    g = live_ref[...].astype(jnp.float32) * inv_temp     # (Kl, bb, bv)
    bv = g.shape[-1]

    m_prev = m_ref[...]                                  # (Kl, bb)
    m_new = jnp.maximum(m_prev, jnp.max(g, axis=-1))
    scale = jnp.exp(m_prev - m_new)
    e = jnp.exp(g - m_new[..., None])                    # (Kl, bb, bv)
    a_ref[...] = a_ref[...] * scale + jnp.sum(e, axis=-1)
    # entropy accumulator U = sum_v e^{g - m} g, rescaled alongside A
    u_ref[...] = u_ref[...] * scale + jnp.sum(e * g, axis=-1)
    m_ref[...] = m_new

    # top-k gather: every received index lives in exactly one vocab block,
    # so the raw scaled logits accumulate with no rescaling.  The j loop is
    # static (J = #peers, small); the one-hot contraction lowers to a
    # batched dot — no (bb, k, bv) product tensor persists across blocks.
    col = jax.lax.broadcasted_iota(jnp.int32, (1, 1, bv), 2) + iv * bv
    J = idx_ref.shape[0]
    for j in range(J):
        match = (idx_ref[j][:, :, None] == col).astype(jnp.float32)
        hit = jnp.einsum("ibv,btv->ibt", g, match,
                         preferred_element_type=jnp.float32)
        gat_ref[:, j] = gat_ref[:, j] + hit

    @pl.when(iv == n_v_blocks - 1)
    def _finish():
        a = a_ref[...]
        z = m_ref[...] + jnp.log(a)                      # (Kl, bb)
        neg_h = u_ref[...] / a - z                       # -H(P_i)
        logp = logp_ref[...].astype(jnp.float32)         # (J, bb, k)
        p_at = jnp.exp(gat_ref[...] - z[:, None, :, None])   # (Kl,J,bb,k)
        residual = jnp.clip(1.0 - jnp.sum(jnp.exp(logp), axis=-1),
                            1e-9, 1.0)                   # (J, bb)
        c = jnp.log(residual / max(V - k, 1))            # true V, not padded
        s = jnp.sum(p_at, axis=-1)                       # (Kl, J, bb)
        cross = jnp.sum(p_at * logp[None], axis=-1)      # (Kl, J, bb)
        kl = neg_h[:, None, :] - c[None] * (1.0 - s) - cross
        w = w_ref[...].astype(jnp.float32)               # (Kl, J)
        out_ref[...] = jnp.sum(kl * w[:, :, None],
                               axis=1).astype(out_ref.dtype)


def _sparse_kl_forward(live, idx, logp_top, pair_w, temperature: float,
                       interpret: bool, block_b: int, block_v: int):
    Kl, B, V = live.shape
    J, _, k = idx.shape
    bb = min(block_b, B)
    bv = min(block_v, V)
    pad_b = (-B) % bb
    pad_v = (-V) % bv
    if pad_b or pad_v:
        # vocab padding uses NEG_INF (e -> 0, products stay 0); padded
        # indices never match padded columns (idx < V <= col)
        live = jnp.pad(live, ((0, 0), (0, pad_b), (0, pad_v)),
                       constant_values=NEG_INF)
    if pad_b:
        idx = jnp.pad(idx, ((0, 0), (0, pad_b), (0, 0)))
        logp_top = jnp.pad(logp_top, ((0, 0), (0, pad_b), (0, 0)))
    Bp, Vp = B + pad_b, V + pad_v
    n_b, n_v = Bp // bb, Vp // bv

    kernel = functools.partial(_sparse_kl_kernel, n_v_blocks=n_v,
                               inv_temp=1.0 / temperature, V=V, k=k)
    out = pl.pallas_call(
        kernel,
        grid=(n_b, n_v),
        in_specs=[pl.BlockSpec((Kl, bb, bv), lambda ib, iv: (0, ib, iv)),
                  pl.BlockSpec((J, bb, k), lambda ib, iv: (0, ib, 0)),
                  pl.BlockSpec((J, bb, k), lambda ib, iv: (0, ib, 0)),
                  pl.BlockSpec((Kl, J), lambda ib, iv: (0, 0))],
        out_specs=pl.BlockSpec((Kl, bb), lambda ib, iv: (0, ib)),
        out_shape=jax.ShapeDtypeStruct((Kl, Bp), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((Kl, bb), jnp.float32),           # running max m
            pltpu.VMEM((Kl, bb), jnp.float32),           # partition A
            pltpu.VMEM((Kl, bb), jnp.float32),           # entropy acc U
            pltpu.VMEM((Kl, J, bb, k), jnp.float32),     # gathered logits
        ],
        interpret=interpret,
    )(live, idx, logp_top, pair_w)
    return out[:, :B]


def _streaming_lse_entropy(blocks):
    """Blocked (Z, -H): (nv, Kl, B, bv) -> ((Kl, B), (Kl, B)).

    One block resident; carries (m, A, U) with U = sum_v e^{g - m} g so
    Z = m + log A and -H = U/A - Z.
    """
    Kl, B = blocks.shape[1], blocks.shape[2]

    def step(carry, blk):
        m, a, u = carry
        m_new = jnp.maximum(m, jnp.max(blk, axis=-1))
        sc = jnp.exp(m - m_new)
        e = jnp.exp(blk - m_new[..., None])
        a = a * sc + jnp.sum(e, axis=-1)
        u = u * sc + jnp.sum(e * blk, axis=-1)
        return (m_new, a, u), None

    (m, a, u), _ = jax.lax.scan(
        step, (jnp.full((Kl, B), NEG_INF, jnp.float32),
               jnp.zeros((Kl, B), jnp.float32),
               jnp.zeros((Kl, B), jnp.float32)), blocks)
    z = m + jnp.log(a)
    return z, u / a - z


def _streaming_sparse_bwd(live, idx, logp_top, pair_w, g_bar,
                          temperature: float, block_v: int):
    """Backward of the pair-weighted sparse KL, streamed over vocab blocks.

    With p/lp the live softmax, a^j_v = sum_t 1[idx_jt == v] (index
    multiplicity), l^j_v = sum_t 1[idx_jt == v] logp_jt, R_i = sum_j w_ij
    and C1_ib = sum_j w_ij (c_jb s_ijb - cross_ijb):

        dlive[i,b,v] = (1/T) gbar_ib p_v [ R_i (lp_v - (-H_ib))
                        + sum_j w_ij (c_jb a^j_v - l^j_v) - C1_ib ]

    Only per-(client, example) statistics and the (J, B, k) received sets
    carry cross-block state; one (Kl, B, bv) block is resident at a time.
    """
    Kl, B, V = live.shape
    J, _, k = idx.shape
    st = 1.0 / temperature
    w = pair_w.astype(jnp.float32)
    L = logp_top.astype(jnp.float32)
    g = live.astype(jnp.float32) * st
    bv = min(block_v, V)
    pad_v = (-V) % bv
    gp = jnp.pad(g, ((0, 0), (0, 0), (0, pad_v)),
                 constant_values=NEG_INF) if pad_v else g
    n_v = (V + pad_v) // bv
    gb = jnp.moveaxis(gp.reshape(Kl, B, n_v, bv), 2, 0)  # (nv, Kl, B, bv)

    z, neg_h = _streaming_lse_entropy(gb)                # (Kl, B) each
    gval = jax.vmap(lambda gi: jax.vmap(
        lambda ij: jnp.take_along_axis(gi, ij, axis=-1))(idx))(g)
    p_at = jnp.exp(gval - z[:, None, :, None])           # (Kl, J, B, k)
    s = jnp.sum(p_at, axis=-1)                           # (Kl, J, B)
    cross = jnp.sum(p_at * L[None], axis=-1)             # (Kl, J, B)
    residual = jnp.clip(1.0 - jnp.sum(jnp.exp(L), axis=-1), 1e-9, 1.0)
    c = jnp.log(residual / max(V - k, 1))                # (J, B)
    r = jnp.sum(w, axis=1)                               # (Kl,)
    c1 = jnp.einsum("ij,ijb->ib", w, c[None] * s - cross)
    gbar = g_bar.astype(jnp.float32)                     # (Kl, B)

    def step(_, xs):
        blk, ivb = xs                                    # (Kl, B, bv)
        col = ivb * bv + jnp.arange(bv)
        lp = blk - z[..., None]
        p = jnp.exp(lp)                                  # 0 on NEG_INF pad
        wterm = jnp.zeros((Kl, B, bv), jnp.float32)
        for j in range(J):
            match = (idx[j][:, :, None] ==
                     col[None, None, :]).astype(jnp.float32)   # (B, k, bv)
            a_j = jnp.sum(match, axis=1)                 # (B, bv)
            l_j = jnp.einsum("btv,bt->bv", match, L[j])  # (B, bv)
            wterm = wterm + w[:, j, None, None] * \
                (c[j][None, :, None] * a_j[None] - l_j[None])
        d = st * gbar[..., None] * p * (
            r[:, None, None] * (lp - neg_h[..., None]) + wterm
            - c1[..., None])
        return None, d

    _, dl = jax.lax.scan(step, None, (gb, jnp.arange(n_v)))
    dl = jnp.moveaxis(dl, 0, 2).reshape(Kl, B, V + pad_v)[:, :, :V]
    return dl.astype(live.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _sparse_kl(live, idx, logp_top, pair_w, temperature, interpret,
               block_b, block_v):
    return _sparse_kl_forward(live, idx, logp_top, pair_w, temperature,
                              interpret, block_b, block_v)


def _sparse_kl_fwd(live, idx, logp_top, pair_w, temperature, interpret,
                   block_b, block_v):
    out = _sparse_kl_forward(live, idx, logp_top, pair_w, temperature,
                             interpret, block_b, block_v)
    return out, (live, idx, logp_top, pair_w)


def _sparse_kl_bwd(temperature, interpret, block_b, block_v, res, g_bar):
    live, idx, logp_top, pair_w = res
    dlive = _streaming_sparse_bwd(live, idx, logp_top, pair_w, g_bar,
                                  temperature, block_v)
    # received indices are integers (tangent space is float0); the received
    # log-probs and pair weights are data (shared constants), not parameters
    return (dlive, np.zeros(idx.shape, jax.dtypes.float0),
            jnp.zeros_like(logp_top), jnp.zeros_like(pair_w))


_sparse_kl.defvjp(_sparse_kl_fwd, _sparse_kl_bwd)


def sparse_kl_topk(live, idx, logp_top, pair_w, *, temperature: float = 1.0,
                   block_b: int = 64, block_v: int = 512,
                   interpret: bool = False):
    """Differentiable pair-weighted sparse KL via the fused streaming kernel.

    live (Kl, B, V) x received top-k sets idx/logp_top (J, B, k) with
    (Kl, J) pair weights -> (Kl, B).  Carries a ``jax.custom_vjp`` whose
    backward streams over vocab blocks (``_streaming_sparse_bwd``);
    cotangents for the received sets and the weights are defined as zero
    (received predictions are data that crossed the client boundary).

    Default blocks are smaller than ``kl_mutual``'s: the gather scratch is
    (Kl, J, bb, k) and must fit VMEM next to the (Kl, bb, bv) live block.
    """
    return _sparse_kl(live, idx, logp_top, pair_w, float(temperature),
                      bool(interpret), int(block_b), int(block_v))
