"""Flat-npz pytree checkpointing with JSON metadata (no orbax dependency).

``save(path, tree, meta)`` / ``restore(path)`` round-trip any pytree of
arrays; tree structure is recorded as '/'-joined key paths.  Works for
params, optimizer state, and client-stacked federated state alike.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(flat: Dict[str, np.ndarray]):
    tree: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return _restore_lists(tree)


def _restore_lists(node):
    """npz keys lose list-ness; restore dicts whose keys are 0..n-1 as lists."""
    if not isinstance(node, dict):
        return node
    node = {k: _restore_lists(v) for k, v in node.items()}
    keys = list(node)
    if keys and all(k.isdigit() for k in keys):
        order = sorted(keys, key=int)
        if [int(k) for k in order] == list(range(len(order))):
            return [node[k] for k in order]
    return node


# npz cannot store ml_dtypes (bfloat16 etc.); view them as a same-width
# integer type and record the true dtype in the JSON sidecar.
_VIEW_FOR_BITS = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}
_NATIVE = {"f", "i", "u", "b", "c"}


def save(path: str, tree, meta: Optional[dict] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    dtypes = {}
    store = {}
    for k, v in flat.items():
        if v.dtype.kind not in _NATIVE:
            dtypes[k] = str(v.dtype)
            v = v.view(_VIEW_FOR_BITS[v.dtype.itemsize])
        store[k] = v
    np.savez(path if path.endswith(".npz") else path + ".npz", **store)
    meta_path = (path[:-4] if path.endswith(".npz") else path) + ".json"
    with open(meta_path, "w") as f:
        json.dump({"meta": meta or {}, "_dtypes": dtypes}, f, indent=2,
                  default=str)


def restore(path: str) -> Tuple[Any, dict]:
    npz_path = path if path.endswith(".npz") else path + ".npz"
    meta_path = (path[:-4] if path.endswith(".npz") else path) + ".json"
    with np.load(npz_path) as data:
        flat = {k: data[k] for k in data.files}
    meta, dtypes = {}, {}
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            doc = json.load(f)
        meta, dtypes = doc.get("meta", {}), doc.get("_dtypes", {})
    if dtypes:
        import ml_dtypes  # noqa: F401 — registers bfloat16 etc. with numpy
        for k, dt in dtypes.items():
            flat[k] = flat[k].view(np.dtype(dt))
    return _unflatten(flat), meta
